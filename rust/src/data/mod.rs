//! Data substrate: in-memory datasets + deterministic synthetic generators.
//!
//! The paper evaluates on MNIST / covtype / HIGGS / RCV1; those downloads
//! are unavailable here, so each family is replaced by a seeded synthetic
//! generator that preserves the properties DeltaGrad's behaviour depends
//! on (n, d, k, class separability, sparsity) — see DESIGN.md §3.

pub mod synth;

use crate::util::Rng;

/// Dense row-major dataset with the bias column already appended
/// (`da = d + 1`, last column all ones) and integer class labels.
#[derive(Clone)]
pub struct Dataset {
    /// n * da row-major features
    pub x: Vec<f32>,
    /// n class labels in [0, k)
    pub y: Vec<u32>,
    pub n: usize,
    pub da: usize,
    pub k: usize,
}

impl Dataset {
    pub fn new(x: Vec<f32>, y: Vec<u32>, da: usize, k: usize) -> Self {
        assert_eq!(x.len() % da, 0);
        let n = x.len() / da;
        assert_eq!(y.len(), n);
        debug_assert!(y.iter().all(|&c| (c as usize) < k));
        Dataset { x, y, n, da, k }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.x[i * self.da..(i + 1) * self.da]
    }

    /// Number of `chunk`-row chunks covering this dataset (last padded).
    pub fn n_chunks(&self, chunk: usize) -> usize {
        self.n.div_ceil(chunk)
    }

    /// Materialize chunk `c` as padded (x, y_onehot, mask) buffers of
    /// exactly `chunk` rows. `removed` marks rows whose mask is zeroed.
    pub fn chunk_padded(
        &self,
        c: usize,
        chunk: usize,
        removed: &IndexSet,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let lo = c * chunk;
        let hi = ((c + 1) * chunk).min(self.n);
        assert!(lo < self.n, "chunk {c} out of range");
        let rows = hi - lo;
        let mut x = vec![0.0f32; chunk * self.da];
        let mut y = vec![0.0f32; chunk * self.k];
        let mut mask = vec![0.0f32; chunk];
        x[..rows * self.da].copy_from_slice(&self.x[lo * self.da..hi * self.da]);
        for r in 0..rows {
            let i = lo + r;
            y[r * self.k + self.y[i] as usize] = 1.0;
            mask[r] = if removed.contains(i) { 0.0 } else { 1.0 };
        }
        (x, y, mask)
    }

    /// Gather `idxs` rows into padded (x, y_onehot, mask) buffers covering
    /// ceil(len/chunk) chunks of `chunk` rows each.
    pub fn gather_padded(&self, idxs: &[usize], chunk: usize) -> Vec<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let mut out = Vec::new();
        for group in idxs.chunks(chunk.max(1)) {
            let mut x = vec![0.0f32; chunk * self.da];
            let mut y = vec![0.0f32; chunk * self.k];
            let mut mask = vec![0.0f32; chunk];
            for (r, &i) in group.iter().enumerate() {
                assert!(i < self.n, "gather index {i} >= n {}", self.n);
                x[r * self.da..(r + 1) * self.da].copy_from_slice(self.row(i));
                y[r * self.k + self.y[i] as usize] = 1.0;
                mask[r] = 1.0;
            }
            out.push((x, y, mask));
        }
        out
    }

    /// Append rows from another dataset (the "addition" scenario).
    pub fn append(&mut self, other: &Dataset) {
        assert_eq!(self.da, other.da);
        assert_eq!(self.k, other.k);
        self.x.extend_from_slice(&other.x);
        self.y.extend_from_slice(&other.y);
        self.n += other.n;
    }

    /// Copy of the subset at `idxs`.
    pub fn subset(&self, idxs: &[usize]) -> Dataset {
        let mut x = Vec::with_capacity(idxs.len() * self.da);
        let mut y = Vec::with_capacity(idxs.len());
        for &i in idxs {
            x.extend_from_slice(self.row(i));
            y.push(self.y[i]);
        }
        Dataset::new(x, y, self.da, self.k)
    }
}

/// Sorted set of removed/selected row indices with O(log n) membership.
/// (Bit-set semantics; kept sorted for deterministic iteration.)
#[derive(Clone, Debug, Default)]
pub struct IndexSet {
    sorted: Vec<usize>,
}

impl IndexSet {
    pub fn empty() -> Self {
        Self::default()
    }

    pub fn from_vec(mut v: Vec<usize>) -> Self {
        v.sort_unstable();
        v.dedup();
        IndexSet { sorted: v }
    }

    pub fn contains(&self, i: usize) -> bool {
        self.sorted.binary_search(&i).is_ok()
    }

    pub fn insert(&mut self, i: usize) -> bool {
        match self.sorted.binary_search(&i) {
            Ok(_) => false,
            Err(pos) => {
                self.sorted.insert(pos, i);
                true
            }
        }
    }

    pub fn len(&self) -> usize {
        self.sorted.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sorted.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = usize> + '_ {
        self.sorted.iter().copied()
    }

    pub fn as_slice(&self) -> &[usize] {
        &self.sorted
    }

    /// Indices in [0, n) NOT in this set.
    pub fn complement(&self, n: usize) -> Vec<usize> {
        let mut out = Vec::with_capacity(n - self.sorted.len());
        let mut it = self.sorted.iter().peekable();
        for i in 0..n {
            if it.peek() == Some(&&i) {
                it.next();
            } else {
                out.push(i);
            }
        }
        out
    }
}

/// Sample a removal set of `r` distinct rows.
pub fn sample_removal(rng: &mut Rng, n: usize, r: usize) -> IndexSet {
    IndexSet::from_vec(rng.sample_distinct(n, r))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        // 5 rows, d=2 (da=3 with bias), k=2
        let x = vec![
            1.0, 2.0, 1.0, //
            3.0, 4.0, 1.0, //
            5.0, 6.0, 1.0, //
            7.0, 8.0, 1.0, //
            9.0, 0.0, 1.0,
        ];
        Dataset::new(x, vec![0, 1, 0, 1, 0], 3, 2)
    }

    #[test]
    fn chunk_padding_and_mask() {
        let ds = tiny();
        assert_eq!(ds.n_chunks(4), 2);
        let removed = IndexSet::from_vec(vec![1]);
        let (x, y, m) = ds.chunk_padded(0, 4, &removed);
        assert_eq!(x.len(), 12);
        assert_eq!(m, vec![1.0, 0.0, 1.0, 1.0]);
        assert_eq!(&y[0..2], &[1.0, 0.0]);
        assert_eq!(&y[2..4], &[0.0, 1.0]);
        let (x2, _y2, m2) = ds.chunk_padded(1, 4, &removed);
        assert_eq!(m2, vec![1.0, 0.0, 0.0, 0.0]); // 1 real row + 3 pad
        assert_eq!(&x2[0..3], ds.row(4));
        assert!(x2[3..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn gather_groups() {
        let ds = tiny();
        let groups = ds.gather_padded(&[0, 2, 4], 2);
        assert_eq!(groups.len(), 2);
        assert_eq!(groups[0].2, vec![1.0, 1.0]);
        assert_eq!(groups[1].2, vec![1.0, 0.0]);
        assert_eq!(&groups[1].0[0..3], ds.row(4));
    }

    #[test]
    fn index_set_ops() {
        let mut s = IndexSet::from_vec(vec![3, 1, 3]);
        assert_eq!(s.len(), 2);
        assert!(s.contains(1) && s.contains(3) && !s.contains(2));
        assert!(s.insert(2));
        assert!(!s.insert(2));
        assert_eq!(s.as_slice(), &[1, 2, 3]);
        assert_eq!(s.complement(5), vec![0, 4]);
    }

    #[test]
    fn append_and_subset() {
        let mut ds = tiny();
        let extra = ds.subset(&[0, 1]);
        ds.append(&extra);
        assert_eq!(ds.n, 7);
        assert_eq!(ds.row(5), extra.row(0));
    }

    #[test]
    fn sample_removal_distinct() {
        let mut rng = Rng::new(1);
        let s = sample_removal(&mut rng, 100, 10);
        assert_eq!(s.len(), 10);
        assert!(s.iter().all(|i| i < 100));
    }
}
