//! Training substrate: GD / minibatch-SGD with the trajectory cache that
//! DeltaGrad consumes.
//!
//! The paper's setup (§2.1–2.2): train T iterations of (S)GD over the
//! full data, caching the parameters `w_t` and the (minibatch-)average
//! gradients `∇F(w_t)` at every step. This module is also reused as
//! **BaseL** — retraining from scratch over the remaining data — by
//! passing a non-empty removal set (and, for SGD, the original minibatch
//! schedule so the randomness matches, §A.1.2).

use anyhow::Result;

use crate::config::HyperParams;
use crate::data::{Dataset, IndexSet};
use crate::runtime::engine::{ModelExes, Stats};
use crate::runtime::Runtime;
use crate::util::vecmath::axpy;
use crate::util::Rng;

/// Cached optimization trajectory from one training run.
#[derive(Clone, Default)]
pub struct Trajectory {
    /// parameters w_0 .. w_T (T+1 vectors of length p)
    pub ws: Vec<Vec<f32>>,
    /// average gradient over the iteration's batch at w_t (T vectors)
    pub gs: Vec<Vec<f32>>,
    /// minibatch indices per iteration; empty vec = full batch (GD)
    pub batches: Vec<Vec<usize>>,
    /// number of training rows the run saw (n - |removed|)
    pub n_effective: usize,
}

impl Trajectory {
    pub fn t(&self) -> usize {
        self.gs.len()
    }

    /// Bytes held by the cache (the paper's "information cached during
    /// the training phase"; used by the memory accounting in benches).
    pub fn approx_bytes(&self) -> usize {
        let f = |v: &Vec<Vec<f32>>| v.iter().map(|x| x.len() * 4).sum::<usize>();
        f(&self.ws) + f(&self.gs) + self.batches.iter().map(|b| b.len() * 8).sum::<usize>()
    }
}

/// Options for one training run.
pub struct TrainOpts<'a> {
    pub hp: &'a HyperParams,
    /// rows excluded from training (BaseL deletion scenario)
    pub removed: &'a IndexSet,
    /// record the (w_t, g_t) trajectory
    pub record: bool,
    /// reuse this minibatch schedule (same-randomness retraining)
    pub reuse_batches: Option<&'a [Vec<usize>]>,
    /// seed for fresh minibatch sampling (ignored when reusing)
    pub seed: u64,
    /// initial parameters; default = deterministic init (zeros for LR,
    /// seeded He-style gaussians for MLP)
    pub init: Option<&'a [f32]>,
}

impl<'a> TrainOpts<'a> {
    pub fn full(hp: &'a HyperParams, removed: &'a IndexSet) -> Self {
        TrainOpts { hp, removed, record: true, reuse_batches: None, seed: 0x5EED, init: None }
    }
}

pub struct TrainOutput {
    pub w: Vec<f32>,
    pub traj: Option<Trajectory>,
    pub seconds: f64,
    pub final_stats: Stats,
}

/// Deterministic initial parameter vector for a model spec.
pub fn init_params(exes: &ModelExes) -> Vec<f32> {
    let spec = &exes.spec;
    match spec.model {
        crate::config::ModelKind::Lr => vec![0.0; spec.p],
        crate::config::ModelKind::Mlp => {
            // He-style init, fixed seed: identical across every run so the
            // cached trajectory and retraining share w_0.
            let mut rng = Rng::new(0xC0FFEE);
            let (da, h, k) = (spec.da, spec.hidden, spec.k);
            let mut w = Vec::with_capacity(spec.p);
            let s1 = (2.0 / da as f64).sqrt() as f32;
            for _ in 0..da * h {
                w.push(rng.gaussian_f32() * s1);
            }
            let s2 = (2.0 / (h + 1) as f64).sqrt() as f32;
            for _ in 0..(h + 1) * k {
                w.push(rng.gaussian_f32() * s2);
            }
            w
        }
    }
}

/// Train for `hp.t` iterations on `ds` minus `opts.removed`.
///
/// GD mode (`hp.batch == 0`): one masked full pass per iteration over the
/// staged dataset. SGD mode: per-iteration minibatch of `hp.batch` rows
/// sampled from the ORIGINAL index space (removed members dropped at use
/// time, so the schedule transfers between runs — paper §3's B − ΔB_t).
pub fn train(
    exes: &ModelExes,
    rt: &Runtime,
    ds: &Dataset,
    opts: &TrainOpts,
) -> Result<TrainOutput> {
    let hp = opts.hp;
    let spec = &exes.spec;
    let t0 = std::time::Instant::now();
    let staged = if hp.batch == 0 {
        Some(exes.stage(rt, ds, opts.removed)?)
    } else {
        None
    };
    let n_eff = ds.n - opts.removed.len();
    assert!(n_eff > 0, "all rows removed");
    let mut w = match opts.init {
        Some(init) => init.to_vec(),
        None => init_params(exes),
    };
    let mut rng = Rng::new(opts.seed);
    let mut traj = Trajectory {
        ws: Vec::new(),
        gs: Vec::new(),
        batches: Vec::new(),
        n_effective: n_eff,
    };
    let mut last_stats = Stats::default();

    for t in 0..hp.t {
        if opts.record {
            traj.ws.push(w.clone());
        }
        let (g_sum, stats, batch, cnt) = if hp.batch == 0 {
            let (g, s) = exes.grad_sum_staged(rt, staged.as_ref().unwrap(), &w)?;
            let cnt = s.cnt;
            (g, s, Vec::new(), cnt)
        } else {
            // sample from the original index space, then drop removed rows
            let batch: Vec<usize> = match opts.reuse_batches {
                Some(b) => b[t].clone(),
                None => (0..hp.batch).map(|_| rng.below(ds.n)).collect(),
            };
            let kept: Vec<usize> = batch
                .iter()
                .copied()
                .filter(|i| !opts.removed.contains(*i))
                .collect();
            if kept.is_empty() {
                // B - ΔB_t == 0: skip the update (paper §3)
                if opts.record {
                    traj.gs.push(vec![0.0; spec.p]);
                    traj.batches.push(batch);
                    traj.ws.pop();
                    traj.ws.push(w.clone());
                }
                continue;
            }
            let (g, s) = exes.grad_sum_rows(rt, ds, &kept, &w)?;
            let cnt = kept.len() as f64;
            (g, s, batch, cnt)
        };
        let lr = hp.lr_at(t);
        let scale = -(lr as f64 / cnt) as f32;
        if opts.record {
            let mut g_avg = g_sum.clone();
            crate::util::vecmath::scale(&mut g_avg, (1.0 / cnt) as f32);
            traj.gs.push(g_avg);
            traj.batches.push(batch);
        }
        axpy(scale, &g_sum, &mut w);
        last_stats = stats;
    }
    if opts.record {
        traj.ws.push(w.clone());
    }
    Ok(TrainOutput {
        w,
        traj: if opts.record { Some(traj) } else { None },
        seconds: t0.elapsed().as_secs_f64(),
        final_stats: last_stats,
    })
}

/// Evaluate mean loss + accuracy of `w` over an entire dataset.
///
/// Stages the dataset for this one call. Anything evaluating the same
/// dataset repeatedly should stage once and use
/// [`ModelExes::eval_staged`] (or a `session::Session`'s resident test
/// set) so the rows ship to the device a single time.
pub fn evaluate(exes: &ModelExes, rt: &Runtime, ds: &Dataset, w: &[f32]) -> Result<Stats> {
    let staged = exes.stage(rt, ds, &IndexSet::empty())?;
    exes.eval_staged(rt, &staged, w)
}
