//! End-to-end integration: artifacts -> PJRT -> train -> delete/add ->
//! DeltaGrad vs BaseL. Requires `make artifacts` (small configs suffice).
//!
//! These tests verify the paper's headline correctness claims at small
//! scale: ‖w^I − w^U‖ is (a) small and (b) at least an order of magnitude
//! below ‖w^U − w*‖ (Theorem 1's o(r/n) vs O(r/n) separation).

use deltagrad::config::HyperParams;
use deltagrad::data::{sample_removal, synth, IndexSet};
use deltagrad::runtime::Engine;
use deltagrad::session::{Edit, SessionBuilder};
use deltagrad::train::{self, TrainOpts};
use deltagrad::util::vecmath::dist2;
use deltagrad::util::Rng;

fn engine() -> Engine {
    Engine::open_default().expect("run `make artifacts` first")
}

fn small_hp() -> HyperParams {
    let mut hp = HyperParams::for_dataset("small");
    hp.t = 60;
    hp.j0 = 8;
    hp.t0 = 5;
    hp
}

#[test]
fn grad_engine_matches_between_staged_and_rows() {
    // sum over staged chunks == sum over explicit row gather
    let mut eng = engine();
    let exes = eng.model("small").unwrap();
    let spec = exes.spec.clone();
    let (train_ds, _) = synth::train_test_for_spec(&spec, 42, Some(500), Some(10));
    let mut rng = Rng::new(9);
    let w: Vec<f32> = (0..spec.p).map(|_| rng.gaussian_f32() * 0.1).collect();
    let staged = exes.stage(&eng.rt, &train_ds, &IndexSet::empty()).unwrap();
    let (g1, s1) = exes.grad_sum_staged(&eng.rt, &staged, &w).unwrap();
    let all: Vec<usize> = (0..train_ds.n).collect();
    let (g2, s2) = exes.grad_sum_rows(&eng.rt, &train_ds, &all, &w).unwrap();
    assert_eq!(s1.cnt, s2.cnt);
    assert!((s1.loss_sum - s2.loss_sum).abs() / s1.loss_sum.abs().max(1.0) < 1e-4);
    let denom = g1.iter().map(|x| x.abs()).fold(1.0f32, f32::max) as f64;
    assert!(dist2(&g1, &g2) / denom < 1e-3, "staged vs rows gradient mismatch");
}

#[test]
fn removed_mask_equals_leave_r_out() {
    // grad(staged with removals) == grad(full) - grad(removed rows)
    let mut eng = engine();
    let exes = eng.model("small").unwrap();
    let spec = exes.spec.clone();
    let (ds, _) = synth::train_test_for_spec(&spec, 1, Some(400), Some(10));
    let mut rng = Rng::new(2);
    let removed = sample_removal(&mut rng, ds.n, 13);
    let w: Vec<f32> = (0..spec.p).map(|_| rng.gaussian_f32() * 0.1).collect();
    let staged_masked = exes.stage(&eng.rt, &ds, &removed).unwrap();
    let (g_masked, sm) = exes.grad_sum_staged(&eng.rt, &staged_masked, &w).unwrap();
    let staged_full = exes.stage(&eng.rt, &ds, &IndexSet::empty()).unwrap();
    let (g_full, _) = exes.grad_sum_staged(&eng.rt, &staged_full, &w).unwrap();
    let (g_rem, _) = exes
        .grad_sum_rows(&eng.rt, &ds, removed.as_slice(), &w)
        .unwrap();
    assert_eq!(sm.cnt as usize, ds.n - removed.len());
    let want: Vec<f32> = g_full.iter().zip(&g_rem).map(|(a, b)| a - b).collect();
    let denom = want.iter().map(|x| x.abs()).fold(1.0f32, f32::max) as f64;
    assert!(dist2(&g_masked, &want) / denom < 1e-3);
}

#[test]
fn training_converges_on_small() {
    let mut eng = engine();
    let exes = eng.model("small").unwrap();
    let spec = exes.spec.clone();
    let (train_ds, test_ds) = synth::train_test_for_spec(&spec, 7, None, None);
    let hp = small_hp();
    let out = train::train(&exes, &eng.rt, &train_ds, &TrainOpts::full(&hp, &IndexSet::empty()))
        .unwrap();
    let stats = train::evaluate(&exes, &eng.rt, &test_ds, &out.w).unwrap();
    assert!(
        stats.accuracy() > 0.7,
        "test accuracy {} too low — training broken",
        stats.accuracy()
    );
    let traj = out.traj.unwrap();
    assert_eq!(traj.ws.len(), hp.t + 1);
    assert_eq!(traj.gs.len(), hp.t);
}

#[test]
fn deltagrad_delete_tracks_basel() {
    let mut eng = engine();
    let spec = eng.spec("small").unwrap().clone();
    let (ds, test) = synth::train_test_for_spec(&spec, 3, None, None);
    let hp = small_hp();
    let session = SessionBuilder::new("small")
        .hyper_params(hp.clone())
        .datasets(ds.clone(), test)
        .build_in(&mut eng)
        .unwrap();

    let mut rng = Rng::new(5);
    let edit = Edit::Delete(sample_removal(&mut rng, ds.n, 10)); // ~1%
    // BaseL: retrain from scratch on remaining
    let basel = session.baseline(&edit).unwrap();
    // DeltaGrad (speculative pass)
    let dg = session.preview(&edit).unwrap();

    let d_star_u = dist2(session.w(), &basel.w); // ‖w* − w^U‖  = O(r/n)
    let d_i_u = dist2(&dg.out.w, &basel.w); //      ‖w^I − w^U‖ = o(r/n)
    assert!(d_star_u > 0.0, "removal should move the optimum");
    assert!(
        d_i_u < 0.2 * d_star_u,
        "DeltaGrad error {d_i_u:.3e} not well below baseline gap {d_star_u:.3e}"
    );
    assert!(dg.out.n_approx > 0, "no approximated iterations ran");
    assert!(dg.out.n_exact >= hp.j0, "burn-in not exact");
}

#[test]
fn deltagrad_add_tracks_basel() {
    let mut eng = engine();
    let spec = eng.spec("small").unwrap().clone();
    let (ds, test) = synth::train_test_for_spec(&spec, 11, None, None);
    let hp = small_hp();
    let session = SessionBuilder::new("small")
        .hyper_params(hp)
        .datasets(ds, test)
        .build_in(&mut eng)
        .unwrap();
    let edit = Edit::Add(synth::addition_rows(&spec, 11, 10));
    // BaseL: retrain on base + added
    let basel = session.baseline(&edit).unwrap();
    let dg = session.preview(&edit).unwrap();
    let d_star_u = dist2(session.w(), &basel.w);
    let d_i_u = dist2(&dg.out.w, &basel.w);
    assert!(
        d_i_u < 0.2 * d_star_u,
        "DeltaGrad-add error {d_i_u:.3e} vs baseline gap {d_star_u:.3e}"
    );
}

#[test]
fn deltagrad_sgd_delete_tracks_basel() {
    let mut eng = engine();
    let spec = eng.spec("small").unwrap().clone();
    let (ds, test) = synth::train_test_for_spec(&spec, 13, None, None);
    let mut hp = small_hp();
    hp.batch = 512; // half the 1024 rows per minibatch
    let session = SessionBuilder::new("small")
        .hyper_params(hp)
        .datasets(ds.clone(), test)
        .build_in(&mut eng)
        .unwrap();
    let mut rng = Rng::new(21);
    let edit = Edit::Delete(sample_removal(&mut rng, ds.n, 10));
    // BaseL with the SAME minibatch schedule (paper §A.1.2)
    let basel = session.baseline_same_batches(&edit).unwrap();
    let dg = session.preview(&edit).unwrap();
    assert_eq!(dg.mode, deltagrad::session::PassMode::Sgd);
    let d_star_u = dist2(session.w(), &basel.w);
    let d_i_u = dist2(&dg.out.w, &basel.w);
    assert!(d_star_u > 0.0);
    assert!(
        d_i_u < 0.5 * d_star_u,
        "SGD DeltaGrad error {d_i_u:.3e} vs baseline gap {d_star_u:.3e}"
    );
}

#[test]
fn lbfgs_artifact_matches_host_implementation() {
    let mut eng = engine();
    let exes = eng.model("small").unwrap();
    let spec = exes.spec.clone();
    let mut rng = Rng::new(31);
    let p = spec.p;
    let m = spec.m;
    // curvature-consistent pairs: dg = c * dw + noise
    let mut dws = Vec::new();
    let mut dgs = Vec::new();
    let mut hist = deltagrad::lbfgs::History::new(m);
    for _ in 0..m {
        let dw: Vec<f32> = (0..p).map(|_| rng.gaussian_f32()).collect();
        let dg: Vec<f32> = dw
            .iter()
            .map(|x| 2.0 * x + 0.05 * rng.gaussian_f32())
            .collect();
        hist.push(dw.clone(), dg.clone());
        dws.push(dw);
        dgs.push(dg);
    }
    let v: Vec<f32> = (0..p).map(|_| rng.gaussian_f32()).collect();
    let host = hist.bv(&v).unwrap();
    let art = exes.lbfgs_bv_artifact(&eng.rt, &dws, &dgs, &v).unwrap();
    let denom = host.iter().map(|x| x.abs()).fold(1.0f32, f32::max) as f64;
    assert!(
        dist2(&host, &art) / denom < 1e-3,
        "host vs artifact L-BFGS mismatch: {:.3e}",
        dist2(&host, &art) / denom
    );
}

#[test]
fn hvp_artifact_consistent_with_grad_difference() {
    // H(w)v ≈ (g(w + eps v) − g(w − eps v)) / (2 eps)
    let mut eng = engine();
    let exes = eng.model("small").unwrap();
    let spec = exes.spec.clone();
    let (ds, _) = synth::train_test_for_spec(&spec, 17, Some(200), Some(10));
    let idxs: Vec<usize> = (0..50).collect();
    let mut rng = Rng::new(23);
    let w: Vec<f32> = (0..spec.p).map(|_| rng.gaussian_f32() * 0.1).collect();
    let v: Vec<f32> = (0..spec.p).map(|_| rng.gaussian_f32()).collect();
    let hv = exes.hvp_sum_rows(&eng.rt, &ds, &idxs, &w, &v).unwrap();
    let eps = 1e-3f32;
    let wp: Vec<f32> = w.iter().zip(&v).map(|(a, b)| a + eps * b).collect();
    let wm: Vec<f32> = w.iter().zip(&v).map(|(a, b)| a - eps * b).collect();
    let (gp, _) = exes.grad_sum_rows(&eng.rt, &ds, &idxs, &wp).unwrap();
    let (gm, _) = exes.grad_sum_rows(&eng.rt, &ds, &idxs, &wm).unwrap();
    let fd: Vec<f32> = gp.iter().zip(&gm).map(|(a, b)| (a - b) / (2.0 * eps)).collect();
    let denom = fd.iter().map(|x| x.abs()).fold(1.0f32, f32::max) as f64;
    assert!(dist2(&hv, &fd) / denom < 5e-2, "{:.3e}", dist2(&hv, &fd) / denom);
}

#[test]
fn mlp_deltagrad_with_curvature_gate() {
    let mut eng = engine();
    let spec = eng.spec("smallnn").unwrap().clone();
    let (ds, test) = synth::train_test_for_spec(&spec, 19, None, None);
    let mut hp = HyperParams::for_dataset("smallnn");
    hp.t = 50;
    hp.j0 = 12;
    hp.t0 = 2;
    let session = SessionBuilder::new("smallnn")
        .hyper_params(hp)
        .datasets(ds.clone(), test)
        .build_in(&mut eng)
        .unwrap();
    let mut rng = Rng::new(29);
    let edit = Edit::Delete(sample_removal(&mut rng, ds.n, 10));
    let basel = session.baseline(&edit).unwrap();
    let dg = session.preview(&edit).unwrap();
    let d_star_u = dist2(session.w(), &basel.w);
    let d_i_u = dist2(&dg.out.w, &basel.w);
    assert!(
        d_i_u < d_star_u,
        "MLP DeltaGrad error {d_i_u:.3e} should beat baseline gap {d_star_u:.3e}"
    );
}
