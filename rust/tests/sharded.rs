//! Sharded session execution (requires `make artifacts`).
//!
//! The shard plane claims are quantitative, so the tests pin them
//! quantitatively:
//!  * S=1 is BYTE-identical to the plain session — same parameter bits,
//!    same artifact bytes (no pool, no layout record, no new code on
//!    the hot path);
//!  * S∈{2,4} reproduces the single-session commit within 1e-5 on the
//!    parameters while the masked-count statistic stays EXACT (the
//!    Kahan tails recombine in f64, so cnt is integer-valued no matter
//!    how the sum splits across shards);
//!  * a fixed S is bitwise deterministic run-to-run (the fixed binary
//!    reduction tree never depends on shard finish order);
//!  * edits scatter to their owning shards only — contiguous ranges for
//!    base rows, round-robin by global added index for committed adds;
//!  * per-shard device traffic per commit is EXACTLY E uploads of p
//!    floats, E fused executions per resident chunk, and E downloads
//!    of p+ACC_EXTRA floats (E = exact iterations), plus one mask
//!    re-upload on the shard owning a deleted row;
//!  * artifacts record the shard layout and a restore re-shards
//!    bitwise-identically (adopting the recorded S, refusing a
//!    mismatched override).

use std::path::PathBuf;

use deltagrad::config::HyperParams;
use deltagrad::data::{synth, IndexSet};
use deltagrad::runtime::engine::ACC_EXTRA;
use deltagrad::runtime::Engine;
use deltagrad::session::{Edit, Query, QueryResult, SessionBuilder, ShardedSession};

fn engine() -> Engine {
    Engine::open_default().expect("run `make artifacts` first")
}

fn small_hp() -> HyperParams {
    let mut hp = HyperParams::for_dataset("small");
    hp.t = 40;
    hp.j0 = 6;
    hp.t0 = 5;
    hp
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn tmp_path(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("deltagrad-test-sharded-{tag}-{}", std::process::id()))
}

/// Build an S-shard session over one fixed (train, test) pair so every
/// variant sees bitwise the same data.
fn build_sharded(eng: &mut Engine, shards: usize) -> ShardedSession {
    let spec = eng.spec("small").unwrap().clone();
    let (ds, test) = synth::train_test_for_spec(&spec, 3, Some(640), Some(64));
    SessionBuilder::new("small")
        .hyper_params(small_hp())
        .datasets(ds, test)
        .shards(shards)
        .build_sharded_in(eng)
        .unwrap()
}

/// The edit script every parity variant replays: a cross-shard delete
/// group, an addition, and a committed-added delete (round-robin owner).
fn apply_script(s: &mut ShardedSession, eng: &Engine) -> (f64, Vec<usize>) {
    let spec = eng.spec("small").unwrap().clone();
    let n = 640;
    s.commit(Edit::Delete(IndexSet::from_vec(vec![5, 300, 611]))).unwrap();
    s.commit(Edit::Add(synth::addition_rows(&spec, 900, 3))).unwrap();
    let c = s.commit(Edit::delete_row(n + 1)).unwrap();
    (c.out.last_stats.cnt, vec![c.out.n_exact, c.out.n_approx])
}

#[test]
fn shard_parity_within_1e5_and_cnt_exact() {
    let mut eng = engine();
    let mut base = build_sharded(&mut eng, 1);
    let (cnt1, iters1) = apply_script(&mut base, &eng);
    assert_eq!(cnt1.fract(), 0.0, "masked count must be integer-valued");
    for shards in [2usize, 4] {
        let mut sharded = build_sharded(&mut eng, shards);
        assert_eq!(sharded.shards(), shards);
        let (cnt_s, iters_s) = apply_script(&mut sharded, &eng);
        assert_eq!(cnt_s, cnt1, "cnt must stay EXACT under S={shards}");
        assert_eq!(iters_s, iters1, "the exact/approx schedule must not depend on S");
        let max_diff = base
            .w()
            .iter()
            .zip(sharded.w())
            .map(|(a, b)| (a - b).abs() as f64)
            .fold(0.0f64, f64::max);
        assert!(
            max_diff <= 1e-5,
            "S={shards} parameters drifted {max_diff:.2e} from S=1 (tolerance 1e-5)"
        );
        // the shard plane actually ran: one tree-reduce per exact iter
        let st = sharded.shard_stats().unwrap().expect("S>1 must expose shard stats");
        assert_eq!(st.shards, shards);
        assert!(st.reduces > 0, "no reductions recorded — commits bypassed the pool?");
        assert_eq!(st.per_shard.len(), shards);
    }
}

#[test]
fn fixed_shard_count_is_bitwise_deterministic() {
    let mut eng = engine();
    let mut a = build_sharded(&mut eng, 2);
    let mut b = build_sharded(&mut eng, 2);
    apply_script(&mut a, &eng);
    apply_script(&mut b, &eng);
    assert_eq!(
        bits(a.w()),
        bits(b.w()),
        "same S, same edits, different bits — the reduction tree leaked finish order"
    );
    let (la, lb) = (a.query(&Query::Loss).unwrap(), b.query(&Query::Loss).unwrap());
    match (&la.result, &lb.result) {
        (
            QueryResult::Loss { test_loss: ta, .. },
            QueryResult::Loss { test_loss: tb, .. },
        ) => assert_eq!(ta.to_bits(), tb.to_bits()),
        other => panic!("wrong reply kinds: {other:?}"),
    }
}

#[test]
fn single_shard_is_byte_identical_to_plain_session() {
    let mut eng = engine();
    let spec = eng.spec("small").unwrap().clone();
    let (ds, test) = synth::train_test_for_spec(&spec, 3, Some(640), Some(64));
    let mut plain = SessionBuilder::new("small")
        .hyper_params(small_hp())
        .datasets(ds.clone(), test.clone())
        .build_in(&mut eng)
        .unwrap();
    let mut one = build_sharded(&mut eng, 1);
    assert!(one.shard_stats().unwrap().is_none(), "S=1 must not spawn a pool");
    assert!(one.spawn_transfers().is_empty());
    plain.commit(Edit::delete_row(7)).unwrap();
    one.commit(Edit::delete_row(7)).unwrap();
    assert_eq!(bits(plain.w()), bits(one.w()), "S=1 must be byte-identical");

    // ...down to the artifact bytes: no layout record is written, so
    // the S=1 file is indistinguishable from a plain session's
    let pp = tmp_path("plain.dgar");
    let ps = tmp_path("s1.dgar");
    let _ = std::fs::remove_file(&pp);
    let _ = std::fs::remove_file(&ps);
    plain.save_artifact(&pp).unwrap();
    one.save_artifact(&ps).unwrap();
    let (ba, bb) = (std::fs::read(&pp).unwrap(), std::fs::read(&ps).unwrap());
    let _ = std::fs::remove_file(&pp);
    let _ = std::fs::remove_file(&ps);
    assert_eq!(ba, bb, "S=1 artifact bytes must match the plain session's");
}

#[test]
fn per_shard_transfer_budgets_are_exact() {
    let mut eng = engine();
    let spec = eng.spec("small").unwrap().clone();
    let (p, chunk) = (spec.p, spec.chunk);
    let mut s = build_sharded(&mut eng, 2);
    let layout = s.layout().expect("S=2 has a layout").clone();

    // spawn staging: x + y + mask per resident chunk (plus the model's
    // two zero-accumulator seed buffers), nothing executed
    for (sh, tr) in s.spawn_transfers().iter().enumerate() {
        let (lo, hi) = layout.range(sh);
        let chunks = (hi - lo).div_ceil(chunk) as u64;
        assert_eq!(tr.uploads, 2 + 3 * chunks, "shard {sh} spawn staging uploads");
        assert_eq!(
            tr.upload_floats,
            (2 * p + ACC_EXTRA) as u64 + chunks * (chunk * spec.da + chunk * spec.k + chunk) as u64,
            "shard {sh} spawn staging floats"
        );
        assert_eq!(tr.execs, 0, "spawn must not execute");
        assert_eq!(tr.downloads, 0, "spawn must not download");
    }

    // one delete owned by shard 0: per shard, per exact iteration, the
    // broadcast costs ONE p-float iterate upload, one fused execution
    // per resident chunk, and ONE (p+ACC_EXTRA)-float accumulator
    // download; the mask flip re-uploads one chunk mask on the owner
    let before = s.shard_stats().unwrap().unwrap();
    let committed = s.commit(Edit::delete_row(0)).unwrap();
    let e = committed.out.n_exact as u64;
    assert!(e > 0);
    let after = s.shard_stats().unwrap().unwrap();
    assert_eq!(after.reduces - before.reduces, e, "one tree-reduce per exact iteration");
    for sh in 0..2 {
        let tr = after.per_shard[sh].since(before.per_shard[sh]);
        let (lo, hi) = layout.range(sh);
        let chunks = (hi - lo).div_ceil(chunk) as u64;
        let owner_extra = u64::from(sh == layout.owner_of_base(0).0);
        assert_eq!(tr.uploads, e + owner_extra, "shard {sh} uploads");
        assert_eq!(
            tr.upload_floats,
            e * p as u64 + owner_extra * chunk as u64,
            "shard {sh} upload floats"
        );
        assert_eq!(tr.execs, e * chunks, "shard {sh} executions");
        assert_eq!(tr.downloads, e, "shard {sh} downloads");
        assert_eq!(
            tr.download_floats,
            e * (p + ACC_EXTRA) as u64,
            "shard {sh} download floats"
        );
        assert_eq!(tr.idx_uploads, 0, "no index payloads on the broadcast path");
    }
}

#[test]
fn edits_scatter_to_owning_shards_only() {
    let mut eng = engine();
    let mut s = build_sharded(&mut eng, 2);
    let layout = s.layout().unwrap().clone();

    // base delete in shard 1's range: only shard 1 pays the mask flip
    let victim = layout.range(1).0 + 3;
    let before = s.shard_stats().unwrap().unwrap();
    let c = s.commit(Edit::delete_row(victim)).unwrap();
    let e = c.out.n_exact as u64;
    let after = s.shard_stats().unwrap().unwrap();
    let d0 = after.per_shard[0].since(before.per_shard[0]);
    let d1 = after.per_shard[1].since(before.per_shard[1]);
    assert_eq!(d0.uploads, e, "shard 0 must see only the broadcast");
    assert_eq!(d1.uploads, e + 1, "shard 1 owns the deleted row's mask");

    // one added row lands round-robin on shard 0 (global added index 0)
    let spec = eng.spec("small").unwrap().clone();
    let before = s.shard_stats().unwrap().unwrap();
    let c = s.commit(Edit::Add(synth::addition_rows(&spec, 901, 1))).unwrap();
    let e = c.out.n_exact as u64;
    let after = s.shard_stats().unwrap().unwrap();
    let d0 = after.per_shard[0].since(before.per_shard[0]);
    let d1 = after.per_shard[1].since(before.per_shard[1]);
    assert!(d0.uploads > e, "shard 0 must stage the added row");
    assert_eq!(d1.uploads, e, "shard 1 owns no added rows yet");

    // deleting that committed-added row hits the same owner; shard 1's
    // execs also pin that it never grew a tail segment
    let before = s.shard_stats().unwrap().unwrap();
    let c = s.commit(Edit::delete_row(640)).unwrap();
    let e = c.out.n_exact as u64;
    let after = s.shard_stats().unwrap().unwrap();
    let d0 = after.per_shard[0].since(before.per_shard[0]);
    let d1 = after.per_shard[1].since(before.per_shard[1]);
    assert_eq!(d0.uploads, e + 1, "the added row's mask flips on its round-robin owner");
    assert_eq!(d1.uploads, e, "shard 1 must not be touched by shard 0's added delete");
    let chunks1 = {
        let (lo, hi) = layout.range(1);
        (hi - lo).div_ceil(spec.chunk) as u64
    };
    assert_eq!(d1.execs, e * chunks1, "shard 1 has no tail segments to execute");
}

#[test]
fn artifact_round_trip_preserves_shard_layout_bitwise() {
    let mut eng = engine();
    let mut live = build_sharded(&mut eng, 2);
    apply_script(&mut live, &eng);
    let rec_live = live.layout().unwrap().to_rec();

    let path = tmp_path("layout.dgar");
    let _ = std::fs::remove_file(&path);
    live.save_artifact(&path).unwrap();

    // shards=1 adopts the recorded layout; the re-derived partition
    // must equal the record and the restored model must be bitwise
    let restored = ShardedSession::restore_from(&path, 1).unwrap();
    assert_eq!(restored.shards(), 2, "restore must adopt the artifact's S");
    assert_eq!(restored.layout().unwrap().to_rec(), rec_live);
    assert_eq!(bits(restored.w()), bits(live.w()), "restore must be bitwise");
    assert_eq!(restored.version(), live.version());

    // a matching explicit S is fine; a mismatched one must refuse
    assert!(ShardedSession::restore_from(&path, 2).is_ok());
    let err = ShardedSession::restore_from(&path, 4).unwrap_err().to_string();
    assert!(err.contains("--shards"), "mismatch error must name the flag: {err}");

    // re-saving the restored session reproduces the artifact bytes —
    // layout record included
    let path2 = tmp_path("layout2.dgar");
    let _ = std::fs::remove_file(&path2);
    restored.save_artifact(&path2).unwrap();
    let (a, b) = (std::fs::read(&path).unwrap(), std::fs::read(&path2).unwrap());
    let _ = std::fs::remove_file(&path);
    let _ = std::fs::remove_file(&path2);
    assert_eq!(a, b, "save → restore → save must be byte-stable");
}
