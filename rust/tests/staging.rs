//! Staged-context equivalence tests (requires `make artifacts`).
//!
//! The PRs that introduced `StagedRows`/`PassCtx` and then the fused
//! reduction + resident-minibatch SGD (see docs/PERFORMANCE.md) claim
//! pure transfer-schedule changes: same floats in, same floats out (up
//! to the documented reduction-order caveat for SGD). These tests pin:
//!  * reusing staged delta rows across parameter updates is BITWISE
//!    identical to the seed per-iteration re-gather path;
//!  * `delete_gd` end-to-end is bitwise identical to a faithful
//!    reproduction of the seed per-iteration-upload loop;
//!  * the per-pass upload counters prove delta rows ship once per PASS
//!    and parameters once per ITERATION;
//!  * every multi-chunk gradient/HVP call downloads exactly ONE result
//!    (the fused on-device reduction);
//!  * resident-mask SGD matches the gather-shaped reference on the seed
//!    shapes, and its exact-iteration upload payload is the per-chunk
//!    multiplicity masks OR — below the density threshold — compact
//!    index lists (O(b) scalars), never the minibatch rows;
//!  * the device-resident CG solver uploads NOTHING per iteration after
//!    its warm-up and downloads one 2-float scalar pair.
//!
//! The free functions under test are deprecated shims over the Session
//! API now; these pins intentionally keep exercising them for one
//! release (tests/session.rs pins the Session path against them).

#![allow(deprecated)]

use deltagrad::config::HyperParams;
use deltagrad::data::{sample_removal, synth, IndexSet};
use deltagrad::deltagrad::batch;
use deltagrad::runtime::Engine;
use deltagrad::session::{Edit, PassMode, SessionBuilder};
use deltagrad::train::{self, TrainOpts};
use deltagrad::util::Rng;

fn engine() -> Engine {
    Engine::open_default().expect("run `make artifacts` first")
}

#[test]
fn staged_rows_reuse_bitwise_matches_regather() {
    let mut eng = engine();
    let exes = eng.model("small").unwrap();
    let spec = exes.spec.clone();
    let (ds, _) = synth::train_test_for_spec(&spec, 51, Some(500), Some(10));
    let mut rng = Rng::new(4);
    let idxs = sample_removal(&mut rng, ds.n, 37);
    let sr = exes.stage_rows(&eng.rt, &ds, idxs.as_slice()).unwrap();
    // several distinct parameter vectors, as a retrain pass would issue
    for trial in 0..5 {
        let w: Vec<f32> = (0..spec.p)
            .map(|_| rng.gaussian_f32() * 0.1)
            .collect();
        let (g_seed, s_seed) = exes.grad_sum_rows(&eng.rt, &ds, idxs.as_slice(), &w).unwrap();
        let ctx = exes.pass_ctx(&eng.rt, &w).unwrap();
        let (g_staged, s_staged) = exes.grad_rows_staged(&eng.rt, &sr, &ctx).unwrap();
        assert_eq!(g_seed, g_staged, "trial {trial}: staged reuse drifted from re-gather");
        assert_eq!(s_seed, s_staged, "trial {trial}: stats drifted");
    }
}

#[test]
fn subset_mask_matches_explicit_gather() {
    let mut eng = engine();
    let exes = eng.model("small").unwrap();
    let spec = exes.spec.clone();
    let (ds, _) = synth::train_test_for_spec(&spec, 13, Some(400), Some(10));
    let mut rng = Rng::new(8);
    let w: Vec<f32> = (0..spec.p).map(|_| rng.gaussian_f32() * 0.1).collect();
    // stage a 200-row pool spanning two chunk_small groups
    let pool: Vec<usize> = (0..200).collect();
    let sr = exes.stage_rows(&eng.rt, &ds, &pool).unwrap();
    let ctx = exes.pass_ctx(&eng.rt, &w).unwrap();
    // subset straddling both groups, with one duplicated position
    let positions = vec![3usize, 40, 150, 199, 40];
    let rows: Vec<usize> = positions.iter().map(|&p| pool[p]).collect();
    let (g_mask, s_mask) = exes.grad_rows_subset(&eng.rt, &sr, &ctx, &positions).unwrap();
    let (g_gather, s_gather) = exes.grad_sum_rows(&eng.rt, &ds, &rows, &w).unwrap();
    assert_eq!(s_mask.cnt, s_gather.cnt, "multiplicity lost");
    let denom = g_gather.iter().map(|x| x.abs()).fold(1.0f32, f32::max) as f64;
    let d = deltagrad::util::vecmath::dist2(&g_mask, &g_gather);
    assert!(d / denom < 1e-5, "subset-mask gradient drifted: {:.3e}", d / denom);
    assert!(
        (s_mask.loss_sum - s_gather.loss_sum).abs() / s_gather.loss_sum.abs().max(1.0) < 1e-5
    );
}

#[test]
fn delete_gd_bitwise_matches_seed_upload_schedule() {
    let mut eng = engine();
    let exes = eng.model("small").unwrap();
    let spec = exes.spec.clone();
    let (ds, _) = synth::train_test_for_spec(&spec, 3, Some(640), Some(10));
    let mut hp = HyperParams::for_dataset("small");
    hp.t = 30;
    hp.j0 = 6;
    hp.t0 = 5;
    let full = train::train(&exes, &eng.rt, &ds, &TrainOpts::full(&hp, &IndexSet::empty()))
        .unwrap();
    let traj = full.traj.unwrap();
    let removed = sample_removal(&mut Rng::new(5), ds.n, 10);
    let w_seed =
        deltagrad::testing::baseline::delete_gd_seed_shape(&exes, &eng.rt, &ds, &traj, &hp, &removed)
            .unwrap();
    let dg = batch::delete_gd(&exes, &eng.rt, &ds, &traj, &hp, &removed).unwrap();
    assert_eq!(
        w_seed, dg.w,
        "staged-context delete_gd drifted from the seed per-iteration-upload path"
    );
}

#[test]
fn delete_gd_uploads_delta_rows_once_per_pass() {
    let mut eng = engine();
    let exes = eng.model("small").unwrap();
    let spec = exes.spec.clone();
    let (ds, _) = synth::train_test_for_spec(&spec, 9, Some(640), Some(10));
    let mut hp = HyperParams::for_dataset("small");
    hp.t = 30;
    hp.j0 = 6;
    let full = train::train(&exes, &eng.rt, &ds, &TrainOpts::full(&hp, &IndexSet::empty()))
        .unwrap();
    let traj = full.traj.unwrap();
    let removed = sample_removal(&mut Rng::new(2), ds.n, 10);
    let dg = batch::delete_gd(&exes, &eng.rt, &ds, &traj, &hp, &removed).unwrap();
    // upload budget of one pass: 3 buffers per full-dataset chunk staged
    // once + 3 buffers per delta-row group staged once + ONE parameter
    // upload per iteration. Nothing else.
    let full_chunks = ds.n.div_ceil(spec.chunk);
    let delta_groups = removed.len().div_ceil(spec.chunk_small);
    let expected = (3 * full_chunks + 3 * delta_groups + hp.t) as u64;
    assert_eq!(
        dg.transfers.uploads, expected,
        "upload schedule changed: got {}, expected 3*{full_chunks} + 3*{delta_groups} + {}",
        dg.transfers.uploads, hp.t
    );
    // download budget of the fused reduction: one result per gradient
    // call — the delta-row gradient every iteration plus the full-data
    // gradient at exact iterations, nothing per chunk
    assert_eq!(
        dg.transfers.downloads,
        (hp.t + dg.n_exact) as u64,
        "download schedule changed (expected T + exact iterations)"
    );
    // and with a pre-staged dataset the full-chunk term disappears
    let staged = exes.stage(&eng.rt, &ds, &IndexSet::empty()).unwrap();
    let dg2 = batch::delete_gd_staged(&exes, &eng.rt, &ds, &staged, &traj, &hp, &removed)
        .unwrap();
    assert_eq!(dg2.transfers.uploads, (3 * delta_groups + hp.t) as u64);
    assert_eq!(dg2.w, dg.w, "staged-dataset reuse changed the result");
}

#[test]
fn fused_reduction_downloads_once_per_gradient_call() {
    let mut eng = engine();
    let exes = eng.model("small").unwrap();
    let spec = exes.spec.clone();
    // three full chunks and two small row groups, so an unfused path
    // would be caught red-handed (3 or 2 downloads instead of 1)
    let (ds, _) = synth::train_test_for_spec(&spec, 17, Some(3 * spec.chunk), Some(10));
    let staged = exes.stage(&eng.rt, &ds, &IndexSet::empty()).unwrap();
    let mut rng = Rng::new(23);
    let w: Vec<f32> = (0..spec.p).map(|_| rng.gaussian_f32() * 0.1).collect();

    let c0 = eng.rt.counters.snapshot();
    exes.grad_sum_staged(&eng.rt, &staged, &w).unwrap();
    let tr = eng.rt.counters.snapshot().since(c0);
    assert_eq!(tr.downloads, 1, "full staged gradient must download once");
    assert_eq!(
        tr.download_floats,
        (spec.p + deltagrad::runtime::engine::ACC_EXTRA) as u64
    );
    assert_eq!(tr.execs, 3, "one execution per chunk is still expected");

    let pool: Vec<usize> = (0..2 * spec.chunk_small).collect();
    let sr = exes.stage_rows(&eng.rt, &ds, &pool).unwrap();
    let ctx = exes.pass_ctx(&eng.rt, &w).unwrap();
    let c0 = eng.rt.counters.snapshot();
    exes.grad_rows_staged(&eng.rt, &sr, &ctx).unwrap();
    let tr = eng.rt.counters.snapshot().since(c0);
    assert_eq!(tr.downloads, 1, "staged-rows gradient must download once");

    let v: Vec<f32> = (0..spec.p).map(|_| rng.gaussian_f32()).collect();
    let c0 = eng.rt.counters.snapshot();
    exes.hvp_rows_staged(&eng.rt, &sr, &ctx, &v).unwrap();
    let tr = eng.rt.counters.snapshot().since(c0);
    assert_eq!(tr.downloads, 1, "HVP must download once");
    assert_eq!(tr.download_floats, spec.p as u64);
}

#[test]
fn staged_subset_sparse_ships_index_lists_only() {
    // the resident-minibatch primitive, sparse side of the density
    // threshold: a 5-row selection over resident Staged chunks executes
    // via the grad_idx_acc gather artifacts — per touched chunk, ONE
    // (i32 idx, f32 mult) pair of idx_cap scalars each, O(b) payload —
    // and must agree with an explicit gather of the same rows
    let mut eng = engine();
    let exes = eng.model("small").unwrap();
    let spec = exes.spec.clone();
    let (ds, _) = synth::train_test_for_spec(&spec, 31, Some(2 * spec.chunk + 64), Some(10));
    let staged = exes.stage(&eng.rt, &ds, &IndexSet::empty()).unwrap();
    let mut rng = Rng::new(5);
    let w: Vec<f32> = (0..spec.p).map(|_| rng.gaussian_f32() * 0.1).collect();
    let ctx = exes.pass_ctx(&eng.rt, &w).unwrap();
    // rows straddling all three chunks, one duplicated (multiplicity 2)
    let rows = vec![3usize, spec.chunk + 40, 2 * spec.chunk + 10, 7, 3];
    let touched = 3u64;
    assert!(spec.idx_list_wins(2), "test presumes sparse rows take the index path");

    let c0 = eng.rt.counters.snapshot();
    let (g_idx, s_idx) = exes.grad_staged_subset(&eng.rt, &staged, &ctx, &rows).unwrap();
    let tr = eng.rt.counters.snapshot().since(c0);
    // one idx buffer + one mult buffer per touched chunk — and the idx
    // payload class is counted separately
    assert_eq!(tr.uploads, 2 * touched, "index-list path ships idx+mult per chunk");
    assert_eq!(tr.upload_floats, 2 * touched * spec.idx_cap as u64);
    assert_eq!(tr.idx_uploads, touched);
    assert_eq!(tr.idx_scalars, touched * spec.idx_cap as u64);
    // O(b) scalars, far below the O(chunk)-float mask payload
    assert!(tr.upload_floats < touched * spec.chunk as u64);
    assert_eq!(tr.downloads, 1, "fused subset gradient must download once");
    assert_eq!(tr.execs, touched);

    let (g_gather, s_gather) = exes.grad_sum_rows(&eng.rt, &ds, &rows, &w).unwrap();
    assert_eq!(s_idx.cnt, s_gather.cnt, "multiplicity lost");
    let denom = g_gather.iter().map(|x| x.abs()).fold(1.0f32, f32::max) as f64;
    let d = deltagrad::util::vecmath::dist2(&g_idx, &g_gather);
    assert!(d / denom < 1e-5, "index-list gradient drifted: {:.3e}", d / denom);
    assert!(
        (s_idx.loss_sum - s_gather.loss_sum).abs() / s_gather.loss_sum.abs().max(1.0) < 1e-5
    );
}

#[test]
fn staged_subset_dense_keeps_mask_path() {
    // dense side of the threshold: selecting most of a chunk would need
    // several index groups, so the auto-select keeps the single
    // chunk-float multiplicity mask — and still matches the gather
    let mut eng = engine();
    let exes = eng.model("small").unwrap();
    let spec = exes.spec.clone();
    let (ds, _) = synth::train_test_for_spec(&spec, 33, Some(spec.chunk), Some(10));
    let staged = exes.stage(&eng.rt, &ds, &IndexSet::empty()).unwrap();
    let mut rng = Rng::new(6);
    let w: Vec<f32> = (0..spec.p).map(|_| rng.gaussian_f32() * 0.1).collect();
    let ctx = exes.pass_ctx(&eng.rt, &w).unwrap();
    let rows: Vec<usize> = (0..200).collect(); // 200 distinct > threshold
    assert!(!spec.idx_list_wins(rows.len()), "test presumes the mask path");

    let c0 = eng.rt.counters.snapshot();
    let (g_mask, s_mask) = exes.grad_staged_subset(&eng.rt, &staged, &ctx, &rows).unwrap();
    let tr = eng.rt.counters.snapshot().since(c0);
    assert_eq!(tr.uploads, 1, "dense subset ships one multiplicity mask");
    assert_eq!(tr.upload_floats, spec.chunk as u64);
    assert_eq!(tr.idx_uploads, 0, "no index payload on the dense path");
    assert_eq!(tr.downloads, 1);

    let (g_gather, s_gather) = exes.grad_sum_rows(&eng.rt, &ds, &rows, &w).unwrap();
    assert_eq!(s_mask.cnt, s_gather.cnt);
    let denom = g_gather.iter().map(|x| x.abs()).fold(1.0f32, f32::max) as f64;
    let d = deltagrad::util::vecmath::dist2(&g_mask, &g_gather);
    assert!(d / denom < 1e-5, "dense-mask gradient drifted: {:.3e}", d / denom);
}

#[test]
fn resident_sgd_matches_gather_shape() {
    // resident multiplicity-mask SGD vs the old per-exact-iteration
    // minibatch gather. NOT bitwise: packing batch rows densely (gather)
    // vs summing them in staged-chunk order (resident) changes the f32
    // reduction order — the pin is a tight relative tolerance plus an
    // identical exact/approx schedule.
    let mut eng = engine();
    let exes = eng.model("small").unwrap();
    let spec = exes.spec.clone();
    let (ds, _) = synth::train_test_for_spec(&spec, 3, Some(640), Some(10));
    let mut hp = HyperParams::for_dataset("small");
    hp.t = 30;
    hp.j0 = 6;
    hp.t0 = 5;
    hp.batch = 512;
    let full = train::train(&exes, &eng.rt, &ds, &TrainOpts::full(&hp, &IndexSet::empty()))
        .unwrap();
    let traj = full.traj.unwrap();
    let removed = sample_removal(&mut Rng::new(5), ds.n, 10);

    let before = deltagrad::testing::baseline::delete_sgd_gather_shape(
        &exes, &eng.rt, &ds, &traj, &hp, &removed,
    )
    .unwrap();
    let after = batch::delete_sgd(&exes, &eng.rt, &ds, &traj, &hp, &removed).unwrap();
    assert_eq!(after.n_exact, before.n_exact, "exact/approx schedule drifted");
    assert_eq!(after.n_approx, before.n_approx);
    let denom = before.w.iter().map(|x| x.abs()).fold(1e-12f32, f32::max) as f64;
    let d = deltagrad::util::vecmath::dist2(&after.w, &before.w);
    assert!(
        d / denom < 1e-3,
        "resident-mask SGD drifted from the gather shape: {:.3e}",
        d / denom
    );
}

#[test]
fn resident_sgd_upload_and_download_budget() {
    // the acceptance budget: the session stages the trajectory's
    // per-iteration minibatch payloads ONCE on the first preview (same
    // mask/index auto-select and totals as the inline path, but staged
    // for ALL iterations), so the first pass ships the schedule + the
    // removal rows + one param vector per executed iteration — and
    // every LATER pass replays the schedule uploads-free. Every
    // gradient call downloads exactly one fused result. All iterations
    // are made exact (j0 >= T) so the schedule is statically
    // replayable.
    let mut eng = engine();
    let spec = eng.spec("small").unwrap().clone();
    let (ds, test) = synth::train_test_for_spec(&spec, 9, Some(640), Some(64));
    let mut hp = HyperParams::for_dataset("small");
    hp.t = 12;
    hp.j0 = 12; // every iteration exact
    hp.batch = 512;
    let session = SessionBuilder::new("small")
        .hyper_params(hp.clone())
        .datasets(ds.clone(), test)
        .build_in(&mut eng)
        .unwrap();
    assert_eq!(session.mode(), PassMode::Sgd);
    let removed = sample_removal(&mut Rng::new(2), ds.n, 10);
    let rem = removed.clone();
    let pv = session.preview(&Edit::Delete(removed)).unwrap();
    assert_eq!(pv.out.n_exact, hp.t, "setup must make every iteration exact");

    // replay the recorded schedule host-side to derive the exact budget
    let cs = spec.chunk_small;
    let c = spec.chunk;
    let rem_groups = rem.len().div_ceil(cs);
    // the staged schedule's one-time payload covers EVERY iteration
    // (it is edit-independent — which batches get skipped depends on
    // the removal set of a particular preview)
    let mut sched_uploads = 0usize;
    // per-pass traffic: params + removed∩batch masks, per executed
    // iteration
    let mut per_pass_uploads = 0usize;
    let mut downloads = 0usize;
    for batch in session.trajectory().batches.iter() {
        let mut per_chunk: std::collections::BTreeMap<usize, std::collections::BTreeSet<usize>> =
            Default::default();
        for &i in batch.iter() {
            per_chunk.entry(i / c).or_default().insert(i);
        }
        for distinct in per_chunk.values().map(|s| s.len()) {
            if spec.idx_list_wins(distinct) {
                sched_uploads += 2 * distinct.div_ceil(spec.idx_cap); // idx + mult
            } else {
                sched_uploads += 1; // one resident chunk-float mask
            }
        }
        let in_r: Vec<usize> = batch
            .iter()
            .filter_map(|i| rem.as_slice().binary_search(i).ok())
            .collect();
        if batch.len() == in_r.len() {
            continue; // B − ΔB_t == 0: iteration skipped entirely
        }
        per_pass_uploads += 1; // the parameter vector
        if !in_r.is_empty() {
            let mut groups: Vec<usize> = in_r.iter().map(|&p| p / cs).collect();
            groups.sort_unstable();
            groups.dedup();
            per_pass_uploads += groups.len(); // removed∩batch multiplicity masks
            downloads += 1; // fused removed∩batch gradient
        }
        downloads += 1; // fused minibatch gradient
    }
    assert_eq!(
        pv.out.transfers.uploads,
        (3 * rem_groups + sched_uploads + per_pass_uploads) as u64,
        "resident SGD first-pass upload schedule changed"
    );
    assert_eq!(
        pv.out.transfers.downloads, downloads as u64,
        "resident SGD download schedule changed"
    );
    // no minibatch row upload: the payload stays a few masks per
    // iteration, nowhere near b·(da+k+1) floats
    let gather_floats = hp.t as u64
        * (hp.batch as u64) * (spec.da + spec.k + 1) as u64;
    assert!(
        pv.out.transfers.upload_floats < gather_floats / 4,
        "mask payload {} should be far below the gather payload {}",
        pv.out.transfers.upload_floats,
        gather_floats
    );

    // a repeat preview replays the STAGED schedule and hits the row
    // cache: the only uploads left are the per-iteration params and the
    // removed∩batch masks — the whole subset payload is resident
    let pv2 = session.preview(&Edit::Delete(rem)).unwrap();
    assert_eq!(
        pv2.out.transfers.uploads, per_pass_uploads as u64,
        "repeated preview must replay the resident schedule uploads-free"
    );
    assert_eq!(pv2.out.w, pv.out.w, "schedule replay changed the floats");
    let stats = session.stats();
    assert_eq!(stats.row_cache_hits, 1);
    assert_eq!(stats.row_cache_misses, 1);
}

#[test]
fn sparse_sgd_minibatch_ships_index_lists() {
    // the index-list acceptance budget: with a minibatch much smaller
    // than the dataset, the staged schedule ships O(b) index scalars
    // (2·idx_cap per touched chunk) ONCE — not O(n) mask floats, and
    // not per pass: replaying the schedule uploads zero index scalars.
    let mut eng = engine();
    let spec = eng.spec("small").unwrap().clone();
    let (ds, test) = synth::train_test_for_spec(&spec, 9, Some(640), Some(64));
    let mut hp = HyperParams::for_dataset("small");
    hp.t = 12;
    hp.j0 = 12; // every iteration exact
    hp.batch = 64; // sparse: ≤ idx_cap distinct rows per chunk (typ.)
    let session = SessionBuilder::new("small")
        .hyper_params(hp.clone())
        .datasets(ds.clone(), test)
        .build_in(&mut eng)
        .unwrap();
    let removed = sample_removal(&mut Rng::new(4), ds.n, 10);
    let rem = removed.clone();
    let pv = session.preview(&Edit::Delete(removed)).unwrap();
    assert_eq!(pv.out.n_exact, hp.t);

    let cs = spec.chunk_small;
    let c = spec.chunk;
    let rem_groups = rem.len().div_ceil(cs);
    let mut sched_uploads = 0usize;
    let mut idx_uploads = 0usize;
    let mut per_pass_uploads = 0usize;
    for batch in session.trajectory().batches.iter() {
        // schedule payload: EVERY iteration stages once (edit-independent)
        let mut per_chunk: std::collections::BTreeMap<usize, std::collections::BTreeSet<usize>> =
            Default::default();
        for &i in batch.iter() {
            per_chunk.entry(i / c).or_default().insert(i);
        }
        for distinct in per_chunk.values().map(|s| s.len()) {
            if spec.idx_list_wins(distinct) {
                let groups = distinct.div_ceil(spec.idx_cap);
                sched_uploads += 2 * groups;
                idx_uploads += groups;
            } else {
                sched_uploads += 1;
            }
        }
        let in_r: Vec<usize> = batch
            .iter()
            .filter_map(|i| rem.as_slice().binary_search(i).ok())
            .collect();
        if batch.len() == in_r.len() {
            continue;
        }
        per_pass_uploads += 1; // parameter vector
        if !in_r.is_empty() {
            let mut groups: Vec<usize> = in_r.iter().map(|&p| p / cs).collect();
            groups.sort_unstable();
            groups.dedup();
            per_pass_uploads += groups.len();
        }
    }
    assert!(idx_uploads > 0, "a b=64 batch must take the index-list path");
    assert_eq!(
        pv.out.transfers.uploads,
        (3 * rem_groups + sched_uploads + per_pass_uploads) as u64,
        "upload schedule changed"
    );
    assert_eq!(pv.out.transfers.idx_uploads, idx_uploads as u64, "index payload class changed");
    assert_eq!(
        pv.out.transfers.idx_scalars,
        (idx_uploads * spec.idx_cap) as u64
    );
    // payload sanity: the whole pass undercuts the gather shape's
    // b·(da+k+1) floats/iter (the exact per-class budget is pinned by
    // the replay above; each idx group is 2·idx_cap scalars where a
    // mask would be `chunk` floats)
    let gather_total = hp.t as u64 * hp.batch as u64 * (spec.da + spec.k + 1) as u64;
    assert!(
        pv.out.transfers.upload_floats < gather_total,
        "index-list pass payload {} should undercut the gather payload {}",
        pv.out.transfers.upload_floats,
        gather_total
    );

    // the uploads-free replay (the PERFORMANCE.md gap, closed): a later
    // pass over the same trajectory ships ZERO index scalars — the
    // resident schedule serves every exact iteration
    let pv2 = session.preview(&Edit::Delete(rem)).unwrap();
    assert_eq!(
        pv2.out.transfers.idx_uploads, 0,
        "schedule replay must not re-ship index lists"
    );
    assert_eq!(
        pv2.out.transfers.uploads, per_pass_uploads as u64,
        "schedule replay must upload params + removal masks only"
    );
    assert_eq!(pv2.out.w, pv.out.w, "schedule replay changed the floats");
}

#[test]
fn sparse_rows_subset_ships_small_index_lists() {
    // small-shape sibling of the staged_subset budget: a sparse position
    // subset of pre-staged chunk_small rows (the robust-stats per-row
    // sweep shape) must ship `idx_cap_small`-capacity index lists —
    // O(1) scalars per selected row — instead of a chunk_small-float
    // mask per touched group, and still agree with an explicit gather.
    // Gated: manifests generated before the `idx_cap_small` key parse as
    // 0 and keep the mask path — nothing to assert there.
    let mut eng = engine();
    let exes = eng.model("small").unwrap();
    let spec = exes.spec.clone();
    if spec.idx_cap_small == 0 {
        eprintln!("manifest predates idx_cap_small; skipping small index-list budget");
        return;
    }
    let icap = spec.idx_cap_small;
    let cs = spec.chunk_small;
    let (ds, _) = synth::train_test_for_spec(&spec, 47, Some(2 * cs + 32), Some(10));
    let mut rng = Rng::new(14);
    let w: Vec<f32> = (0..spec.p).map(|_| rng.gaussian_f32() * 0.1).collect();
    let pool: Vec<usize> = (0..2 * cs).collect();
    let sr = exes.stage_rows(&eng.rt, &ds, &pool).unwrap();
    let ctx = exes.pass_ctx(&eng.rt, &w).unwrap();

    // sparse: 2 distinct slots in group 0 (one duplicated), 1 in group 1
    let positions = vec![3usize, 40, cs + 7, 3];
    assert!(spec.idx_list_wins_small(2), "test presumes the index path wins");
    let touched = 2u64;
    let c0 = eng.rt.counters.snapshot();
    let (g_idx, s_idx) = exes.grad_rows_subset(&eng.rt, &sr, &ctx, &positions).unwrap();
    let tr = eng.rt.counters.snapshot().since(c0);
    assert_eq!(tr.uploads, 2 * touched, "index path ships idx+mult per touched group");
    assert_eq!(tr.upload_floats, 2 * touched * icap as u64);
    assert_eq!(tr.idx_uploads, touched);
    assert_eq!(tr.idx_scalars, touched * icap as u64);
    assert!(
        tr.upload_floats < touched * cs as u64,
        "index payload must undercut the chunk_small-float masks"
    );
    assert_eq!(tr.downloads, 1, "fused subset gradient must download once");
    assert_eq!(tr.execs, touched);

    let rows: Vec<usize> = positions.iter().map(|&p| pool[p]).collect();
    let (g_gather, s_gather) = exes.grad_sum_rows(&eng.rt, &ds, &rows, &w).unwrap();
    assert_eq!(s_idx.cnt, s_gather.cnt, "multiplicity lost on the index path");
    let denom = g_gather.iter().map(|x| x.abs()).fold(1.0f32, f32::max) as f64;
    let d = deltagrad::util::vecmath::dist2(&g_idx, &g_gather);
    assert!(d / denom < 1e-5, "small index-list gradient drifted: {:.3e}", d / denom);

    // dense side of the threshold: selecting most of one group keeps
    // the single chunk_small-float multiplicity mask
    let dense: Vec<usize> = (0..cs - 1).collect();
    assert!(!spec.idx_list_wins_small(dense.len()), "test presumes the mask path");
    let c0 = eng.rt.counters.snapshot();
    let (g_mask, s_mask) = exes.grad_rows_subset(&eng.rt, &sr, &ctx, &dense).unwrap();
    let tr = eng.rt.counters.snapshot().since(c0);
    assert_eq!(tr.uploads, 1, "dense subset ships one multiplicity mask");
    assert_eq!(tr.upload_floats, cs as u64);
    assert_eq!(tr.idx_uploads, 0, "no index payload on the dense path");
    let rows: Vec<usize> = dense.iter().map(|&p| pool[p]).collect();
    let (g_gather, s_gather) = exes.grad_sum_rows(&eng.rt, &ds, &rows, &w).unwrap();
    assert_eq!(s_mask.cnt, s_gather.cnt);
    let denom = g_gather.iter().map(|x| x.abs()).fold(1.0f32, f32::max) as f64;
    let d = deltagrad::util::vecmath::dist2(&g_mask, &g_gather);
    assert!(d / denom < 1e-5, "dense-mask gradient drifted: {:.3e}", d / denom);
}

#[test]
fn resident_cg_uploads_nothing_per_iteration() {
    // the resident-CG acceptance budget: after the warm-up (sample rows
    // + parameter vector + packed state + constants) every CG iteration
    // uploads ZERO buffers and downloads exactly one 2-float scalar
    // pair; the solution comes home once at the end — and the solve
    // actually inverts (H/navg + damp·I).
    let mut eng = engine();
    let exes = eng.model("small").unwrap();
    let spec = exes.spec.clone();
    let (ds, _) = synth::train_test_for_spec(&spec, 41, Some(512), Some(10));
    let rows: Vec<usize> = (0..256).collect();
    let mut rng = Rng::new(11);
    let w: Vec<f32> = (0..spec.p).map(|_| rng.gaussian_f32() * 0.1).collect();
    let b: Vec<f32> = (0..spec.p).map(|_| rng.gaussian_f32()).collect();
    let damp = 0.1f32;
    let iters = 25usize;

    let c0 = eng.rt.counters.snapshot();
    let z = deltagrad::apps::influence::cg_solve_hvp(
        &exes, &eng.rt, &ds, &rows, &w, &b, damp, iters, 0.0, // tol=0: run all iters
    )
    .unwrap();
    let tr = eng.rt.counters.snapshot().since(c0);
    let sample_groups = rows.len().div_ceil(spec.chunk_small);
    // warm-up only: 3 buffers per sample group + w + state + consts
    assert_eq!(
        tr.uploads,
        (3 * sample_groups + 3) as u64,
        "CG iterations must upload nothing after warm-up"
    );
    // per iteration: one 2-float scalar pair; plus the final [p] result
    assert_eq!(tr.downloads, (iters + 1) as u64);
    assert_eq!(tr.download_floats, (2 * iters + spec.p) as u64);
    // per iteration: dir + per-group HVP + step + scalars; final result
    assert_eq!(tr.execs, (iters * (3 + sample_groups) + 1) as u64);

    // correctness: residual of (H/navg + damp I) z = b is small
    let hz = exes.hvp_sum_rows(&eng.rt, &ds, &rows, &w, &z).unwrap();
    let mut resid = 0.0f64;
    let mut bn = 0.0f64;
    for i in 0..spec.p {
        let az = hz[i] as f64 / rows.len() as f64 + damp as f64 * z[i] as f64;
        resid += (az - b[i] as f64).powi(2);
        bn += (b[i] as f64).powi(2);
    }
    assert!(
        resid.sqrt() / bn.sqrt() < 1e-2,
        "resident CG failed to solve: rel resid {:.3e}",
        resid.sqrt() / bn.sqrt()
    );
}

#[test]
fn update_removed_skips_untouched_chunks() {
    let mut eng = engine();
    let exes = eng.model("small").unwrap();
    let spec = exes.spec.clone();
    let (ds, _) = synth::train_test_for_spec(&spec, 29, Some(3 * spec.chunk), Some(10));
    let mut staged = exes.stage(&eng.rt, &ds, &IndexSet::empty()).unwrap();
    // removal confined to chunk 1: exactly one mask re-upload
    let removed = IndexSet::from_vec(vec![spec.chunk + 3, spec.chunk + 7]);
    let n1 = exes.update_removed(&eng.rt, &mut staged, &removed).unwrap();
    assert_eq!(n1, 1, "only the touched chunk should re-upload");
    // same set again: nothing changes
    let n2 = exes.update_removed(&eng.rt, &mut staged, &removed).unwrap();
    assert_eq!(n2, 0);
    // restoring one row touches the same chunk again
    let removed2 = IndexSet::from_vec(vec![spec.chunk + 3]);
    let n3 = exes.update_removed(&eng.rt, &mut staged, &removed2).unwrap();
    assert_eq!(n3, 1);
    // masked gradient agrees with leave-r-out arithmetic after updates
    let mut rng = Rng::new(6);
    let w: Vec<f32> = (0..spec.p).map(|_| rng.gaussian_f32() * 0.1).collect();
    let (g_masked, sm) = exes.grad_sum_staged(&eng.rt, &staged, &w).unwrap();
    assert_eq!(sm.cnt as usize, ds.n - removed2.len());
    let staged_fresh = exes.stage(&eng.rt, &ds, &removed2).unwrap();
    let (g_fresh, _) = exes.grad_sum_staged(&eng.rt, &staged_fresh, &w).unwrap();
    assert_eq!(g_masked, g_fresh, "incremental mask update drifted from fresh staging");
}
