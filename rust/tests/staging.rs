//! Staged-context equivalence tests (requires `make artifacts`).
//!
//! The PR that introduced `StagedRows`/`PassCtx` (see docs/PERFORMANCE.md)
//! claims the refactor is a pure transfer-schedule change: same floats in,
//! same floats out. These tests pin that down:
//!  * reusing staged delta rows across parameter updates is BITWISE
//!    identical to the seed per-iteration re-gather path;
//!  * `delete_gd` end-to-end is bitwise identical to a faithful
//!    reproduction of the seed per-iteration-upload loop;
//!  * the per-pass upload counters prove delta rows ship once per PASS
//!    and parameters once per ITERATION.
//!
//! The free functions under test are deprecated shims over the Session
//! API now; these pins intentionally keep exercising them for one
//! release (tests/session.rs pins the Session path against them).

#![allow(deprecated)]

use deltagrad::config::HyperParams;
use deltagrad::data::{sample_removal, synth, IndexSet};
use deltagrad::deltagrad::batch;
use deltagrad::runtime::Engine;
use deltagrad::train::{self, TrainOpts};
use deltagrad::util::Rng;

fn engine() -> Engine {
    Engine::open_default().expect("run `make artifacts` first")
}

#[test]
fn staged_rows_reuse_bitwise_matches_regather() {
    let mut eng = engine();
    let exes = eng.model("small").unwrap();
    let spec = exes.spec.clone();
    let (ds, _) = synth::train_test_for_spec(&spec, 51, Some(500), Some(10));
    let mut rng = Rng::new(4);
    let idxs = sample_removal(&mut rng, ds.n, 37);
    let sr = exes.stage_rows(&eng.rt, &ds, idxs.as_slice()).unwrap();
    // several distinct parameter vectors, as a retrain pass would issue
    for trial in 0..5 {
        let w: Vec<f32> = (0..spec.p)
            .map(|_| rng.gaussian_f32() * 0.1)
            .collect();
        let (g_seed, s_seed) = exes.grad_sum_rows(&eng.rt, &ds, idxs.as_slice(), &w).unwrap();
        let ctx = exes.pass_ctx(&eng.rt, &w).unwrap();
        let (g_staged, s_staged) = exes.grad_rows_staged(&eng.rt, &sr, &ctx).unwrap();
        assert_eq!(g_seed, g_staged, "trial {trial}: staged reuse drifted from re-gather");
        assert_eq!(s_seed, s_staged, "trial {trial}: stats drifted");
    }
}

#[test]
fn subset_mask_matches_explicit_gather() {
    let mut eng = engine();
    let exes = eng.model("small").unwrap();
    let spec = exes.spec.clone();
    let (ds, _) = synth::train_test_for_spec(&spec, 13, Some(400), Some(10));
    let mut rng = Rng::new(8);
    let w: Vec<f32> = (0..spec.p).map(|_| rng.gaussian_f32() * 0.1).collect();
    // stage a 200-row pool spanning two chunk_small groups
    let pool: Vec<usize> = (0..200).collect();
    let sr = exes.stage_rows(&eng.rt, &ds, &pool).unwrap();
    let ctx = exes.pass_ctx(&eng.rt, &w).unwrap();
    // subset straddling both groups, with one duplicated position
    let positions = vec![3usize, 40, 150, 199, 40];
    let rows: Vec<usize> = positions.iter().map(|&p| pool[p]).collect();
    let (g_mask, s_mask) = exes.grad_rows_subset(&eng.rt, &sr, &ctx, &positions).unwrap();
    let (g_gather, s_gather) = exes.grad_sum_rows(&eng.rt, &ds, &rows, &w).unwrap();
    assert_eq!(s_mask.cnt, s_gather.cnt, "multiplicity lost");
    let denom = g_gather.iter().map(|x| x.abs()).fold(1.0f32, f32::max) as f64;
    let d = deltagrad::util::vecmath::dist2(&g_mask, &g_gather);
    assert!(d / denom < 1e-5, "subset-mask gradient drifted: {:.3e}", d / denom);
    assert!(
        (s_mask.loss_sum - s_gather.loss_sum).abs() / s_gather.loss_sum.abs().max(1.0) < 1e-5
    );
}

#[test]
fn delete_gd_bitwise_matches_seed_upload_schedule() {
    let mut eng = engine();
    let exes = eng.model("small").unwrap();
    let spec = exes.spec.clone();
    let (ds, _) = synth::train_test_for_spec(&spec, 3, Some(640), Some(10));
    let mut hp = HyperParams::for_dataset("small");
    hp.t = 30;
    hp.j0 = 6;
    hp.t0 = 5;
    let full = train::train(&exes, &eng.rt, &ds, &TrainOpts::full(&hp, &IndexSet::empty()))
        .unwrap();
    let traj = full.traj.unwrap();
    let removed = sample_removal(&mut Rng::new(5), ds.n, 10);
    let w_seed =
        deltagrad::testing::baseline::delete_gd_seed_shape(&exes, &eng.rt, &ds, &traj, &hp, &removed)
            .unwrap();
    let dg = batch::delete_gd(&exes, &eng.rt, &ds, &traj, &hp, &removed).unwrap();
    assert_eq!(
        w_seed, dg.w,
        "staged-context delete_gd drifted from the seed per-iteration-upload path"
    );
}

#[test]
fn delete_gd_uploads_delta_rows_once_per_pass() {
    let mut eng = engine();
    let exes = eng.model("small").unwrap();
    let spec = exes.spec.clone();
    let (ds, _) = synth::train_test_for_spec(&spec, 9, Some(640), Some(10));
    let mut hp = HyperParams::for_dataset("small");
    hp.t = 30;
    hp.j0 = 6;
    let full = train::train(&exes, &eng.rt, &ds, &TrainOpts::full(&hp, &IndexSet::empty()))
        .unwrap();
    let traj = full.traj.unwrap();
    let removed = sample_removal(&mut Rng::new(2), ds.n, 10);
    let dg = batch::delete_gd(&exes, &eng.rt, &ds, &traj, &hp, &removed).unwrap();
    // upload budget of one pass: 3 buffers per full-dataset chunk staged
    // once + 3 buffers per delta-row group staged once + ONE parameter
    // upload per iteration. Nothing else.
    let full_chunks = ds.n.div_ceil(spec.chunk);
    let delta_groups = removed.len().div_ceil(spec.chunk_small);
    let expected = (3 * full_chunks + 3 * delta_groups + hp.t) as u64;
    assert_eq!(
        dg.transfers.uploads, expected,
        "upload schedule changed: got {}, expected 3*{full_chunks} + 3*{delta_groups} + {}",
        dg.transfers.uploads, hp.t
    );
    // and with a pre-staged dataset the full-chunk term disappears
    let staged = exes.stage(&eng.rt, &ds, &IndexSet::empty()).unwrap();
    let dg2 = batch::delete_gd_staged(&exes, &eng.rt, &ds, &staged, &traj, &hp, &removed)
        .unwrap();
    assert_eq!(dg2.transfers.uploads, (3 * delta_groups + hp.t) as u64);
    assert_eq!(dg2.w, dg.w, "staged-dataset reuse changed the result");
}

#[test]
fn update_removed_skips_untouched_chunks() {
    let mut eng = engine();
    let exes = eng.model("small").unwrap();
    let spec = exes.spec.clone();
    let (ds, _) = synth::train_test_for_spec(&spec, 29, Some(3 * spec.chunk), Some(10));
    let mut staged = exes.stage(&eng.rt, &ds, &IndexSet::empty()).unwrap();
    // removal confined to chunk 1: exactly one mask re-upload
    let removed = IndexSet::from_vec(vec![spec.chunk + 3, spec.chunk + 7]);
    let n1 = exes.update_removed(&eng.rt, &mut staged, &ds, &removed).unwrap();
    assert_eq!(n1, 1, "only the touched chunk should re-upload");
    // same set again: nothing changes
    let n2 = exes.update_removed(&eng.rt, &mut staged, &ds, &removed).unwrap();
    assert_eq!(n2, 0);
    // restoring one row touches the same chunk again
    let removed2 = IndexSet::from_vec(vec![spec.chunk + 3]);
    let n3 = exes.update_removed(&eng.rt, &mut staged, &ds, &removed2).unwrap();
    assert_eq!(n3, 1);
    // masked gradient agrees with leave-r-out arithmetic after updates
    let mut rng = Rng::new(6);
    let w: Vec<f32> = (0..spec.p).map(|_| rng.gaussian_f32() * 0.1).collect();
    let (g_masked, sm) = exes.grad_sum_staged(&eng.rt, &staged, &w).unwrap();
    assert_eq!(sm.cnt as usize, ds.n - removed2.len());
    let staged_fresh = exes.stage(&eng.rt, &ds, &removed2).unwrap();
    let (g_fresh, _) = exes.grad_sum_staged(&eng.rt, &staged_fresh, &w).unwrap();
    assert_eq!(g_masked, g_fresh, "incremental mask update drifted from fresh staging");
}
