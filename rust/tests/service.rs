//! Coordinator integration: the unlearning service end to end — both
//! planes (edits through the group-commit batcher, typed read queries
//! answered between passes). Requires `make artifacts`.

use std::time::Duration;

use deltagrad::config::HyperParams;
use deltagrad::coordinator::{BatchPolicy, Rejected, ServiceConfig, ServiceHandle};
use deltagrad::runtime::TransferStats;
use deltagrad::session::{Edit, Query, QueryResult, SessionBuilder};

fn small_cfg(policy: BatchPolicy) -> ServiceConfig {
    let mut hp = HyperParams::for_dataset("small");
    hp.t = 40;
    hp.j0 = 6;
    hp.t0 = 5;
    ServiceConfig {
        model: "small".into(),
        seed: 77,
        n_train: Some(512),
        n_test: Some(256),
        hp,
        policy,
        readers: 0,
        query_cache: 0,
        query_cache_bytes: 0,
        shards: 1,
        checkpoint_every: 0,
        checkpoint_dir: None,
        checkpoint_keep: 0,
        wal: false,
        restore_latest: false,
        store_fresh: false,
        supervision: deltagrad::coordinator::Supervision::default(),
        faults: None,
        certify: None,
    }
}

#[test]
fn serves_sequential_deletions() {
    let svc = ServiceHandle::spawn(small_cfg(BatchPolicy {
        max_group: 1,
        max_wait: Duration::from_millis(1),
        ..BatchPolicy::default()
    }))
    .unwrap();
    let snap0 = svc.snapshot().unwrap();
    assert_eq!(snap0.version, 0);
    assert_eq!(snap0.n_train, 512);
    assert!(snap0.test_accuracy > 0.5, "initial acc {}", snap0.test_accuracy);

    for i in 0..3 {
        let rep = svc.update(Edit::delete_row(i)).unwrap();
        assert_eq!(rep.version, (i + 1) as u64);
        assert_eq!(rep.group_size, 1);
        assert!(rep.n_exact > 0);
    }
    let snap = svc.snapshot().unwrap();
    assert_eq!(snap.version, 3);
    assert_eq!(snap.n_train, 509);
    assert!(snap.test_accuracy > 0.5);

    let m = svc.metrics().unwrap();
    assert_eq!(m.requests, 3);
    assert_eq!(m.groups, 3);
    assert_eq!(m.deletes, 3);
    assert_eq!(m.adds, 0);
    svc.shutdown().unwrap();
}

#[test]
fn stopped_service_rejects_typed_instead_of_panicking() {
    // an SGD config makes the worker refuse service and exit right
    // after spawn — the handle then faces a dead service
    let mut cfg = small_cfg(BatchPolicy::default());
    cfg.hp.batch = 512;
    let svc = ServiceHandle::spawn(cfg).unwrap();
    // whichever side of the shutdown race the send lands on, the client
    // gets a typed Stopped — never a panic, never a hang
    match svc.update(Edit::delete_row(0)) {
        Err(Rejected::Stopped) => {}
        other => panic!("expected Rejected::Stopped, got {other:?}"),
    }
    match svc.query(Query::Loss) {
        Err(Rejected::Stopped) => {}
        other => panic!("expected Rejected::Stopped, got {other:?}"),
    }
    assert!(svc.snapshot().is_err(), "snapshot on a dead service must error, not panic");
    // drop (not shutdown) tears the handle down; the worker's own error
    // is its exit status, not ours
}

#[test]
fn group_commit_coalesces_concurrent_requests() {
    let svc = ServiceHandle::spawn(small_cfg(BatchPolicy {
        max_group: 8,
        max_wait: Duration::from_millis(150),
        ..BatchPolicy::default()
    }))
    .unwrap();
    // enqueue 5 requests quickly without waiting
    let rxs: Vec<_> = (10..15)
        .map(|i| svc.update_async(Edit::delete_row(i)).unwrap())
        .collect();
    let mut versions = Vec::new();
    let mut group_sizes = Vec::new();
    for rx in rxs {
        let rep = rx.recv().unwrap().unwrap();
        versions.push(rep.version);
        group_sizes.push(rep.group_size);
    }
    // all five should have been committed together (single version bump)
    assert!(
        group_sizes.iter().all(|&g| g == 5),
        "expected one group of 5, got {group_sizes:?}"
    );
    assert!(versions.iter().all(|&v| v == versions[0]));
    let m = svc.metrics().unwrap();
    assert_eq!(m.requests, 5);
    assert_eq!(m.groups, 1);
    assert!((m.mean_group_size() - 5.0).abs() < 1e-9);
    svc.shutdown().unwrap();
}

#[test]
fn committed_group_uploads_delta_rows_exactly_once() {
    // transfer-accounting regression (docs/PERFORMANCE.md budget): one
    // committed group of k deletes ships
    //   3·⌈k/chunk_small⌉ buffers  (the delta rows, once per PASS)
    //   + T                        (one parameter upload per iteration)
    //   + the touched removal-mask chunks (flipped in place post-pass)
    // and NOTHING else — the base dataset and test set are resident.
    // shape info straight from the manifest (no second PJRT client)
    let dir = deltagrad::config::artifacts_dir().expect("make artifacts");
    let specs = deltagrad::config::parse_manifest(&dir.join("manifest.txt")).unwrap();
    let spec = specs["small"].clone();
    let cfg = small_cfg(BatchPolicy {
        max_group: 8,
        max_wait: Duration::from_millis(150),
        ..BatchPolicy::default()
    });
    let hp_t = cfg.hp.t;
    let svc = ServiceHandle::spawn(cfg).unwrap();
    // k deletes, all inside the first staged chunk -> exactly one mask
    // re-upload when the commit flips them
    let k = 3usize;
    let rxs: Vec<_> = (0..k)
        .map(|i| svc.update_async(Edit::delete_row(i)).unwrap())
        .collect();
    for rx in rxs {
        let rep = rx.recv().unwrap().unwrap();
        assert_eq!(rep.group_size, k, "test assumes one group commit");
    }
    let m = svc.metrics().unwrap();
    let delta_groups = k.div_ceil(spec.chunk_small);
    let touched_chunks = 1; // rows 0..k live in staged chunk 0 (k << chunk)
    assert!(k < spec.chunk, "victims must share one chunk for this budget");
    let expected = (3 * delta_groups + hp_t + touched_chunks) as u64;
    assert_eq!(
        m.uploads, expected,
        "committed group upload budget changed: got {}, expected \
         3*{delta_groups} + {hp_t} + {touched_chunks}",
        m.uploads
    );
    // fused-reduction download budget: the group's signed delta gradient
    // downloads once per iteration, the current-data gradient once per
    // exact iteration — never one literal per chunk
    assert_eq!(
        m.downloads,
        hp_t as u64 + m.exact_iters,
        "committed group download budget changed"
    );
    // exactly one pass-worth of executions was recorded
    assert_eq!(m.groups, 1);
    svc.shutdown().unwrap();
}

#[test]
fn rejects_double_delete_but_keeps_serving() {
    let svc = ServiceHandle::spawn(small_cfg(BatchPolicy {
        max_group: 1,
        max_wait: Duration::from_millis(1),
        ..BatchPolicy::default()
    }))
    .unwrap();
    svc.update(Edit::delete_row(0)).unwrap();
    let err = svc.update(Edit::delete_row(0));
    match err {
        Err(Rejected::Failed(msg)) => assert!(msg.contains("already deleted"), "{msg}"),
        other => panic!("double delete must be rejected as Failed, got {other:?}"),
    }
    // the service must still be healthy
    let rep = svc.update(Edit::delete_row(1)).unwrap();
    assert!(rep.version >= 2);
    svc.shutdown().unwrap();
}

#[test]
fn addition_requests_grow_the_dataset() {
    let svc = ServiceHandle::spawn(small_cfg(BatchPolicy {
        max_group: 1,
        max_wait: Duration::from_millis(1),
        ..BatchPolicy::default()
    }))
    .unwrap();
    let snap0 = svc.snapshot().unwrap();
    // fabricate a plausible sample: zeros with bias column
    let k = 3; // small: k=3
    let da = snap0.w.len() / k;
    let mut x = vec![0.0f32; da];
    x[da - 1] = 1.0;
    let rep = svc.update(Edit::add_row(x, 1, k)).unwrap();
    assert_eq!(rep.version, 1);
    let snap = svc.snapshot().unwrap();
    assert_eq!(snap.n_train, 513);
    let m = svc.metrics().unwrap();
    assert_eq!(m.adds, 1);
    svc.shutdown().unwrap();
}

#[test]
fn interleaved_queries_carry_committed_versions() {
    // the snapshot-consistency contract: every QueryReply.version is a
    // version the worker actually committed (or the initial 0), replies
    // are monotone in request order, and reads never block on the write
    // batcher's max_wait
    let svc = ServiceHandle::spawn(small_cfg(BatchPolicy {
        max_group: 2,
        max_wait: Duration::from_millis(30),
        ..BatchPolicy::default()
    }))
    .unwrap();
    let mut edit_rxs = Vec::new();
    let mut query_versions = Vec::new();
    for i in 0..6 {
        edit_rxs.push(svc.update_async(Edit::delete_row(i)).unwrap());
        let rep = svc.query(Query::Loss).unwrap();
        match rep.result {
            QueryResult::Loss { test_accuracy, .. } => {
                assert!(test_accuracy.is_finite());
            }
            other => panic!("wrong reply kind: {other:?}"),
        }
        query_versions.push(rep.version);
    }
    // the set of versions the worker reported committing
    let mut committed: std::collections::BTreeSet<u64> = [0u64].into_iter().collect();
    for rx in edit_rxs {
        committed.insert(rx.recv().unwrap().unwrap().version);
    }
    for (i, v) in query_versions.iter().enumerate() {
        assert!(
            committed.contains(v),
            "query {i} was answered at v{v}, which the worker never committed \
             (committed: {committed:?})"
        );
    }
    assert!(
        query_versions.windows(2).all(|w| w[0] <= w[1]),
        "reply versions must be monotone: {query_versions:?}"
    );
    // the final snapshot is the largest committed version
    let snap = svc.snapshot().unwrap();
    assert_eq!(Some(&snap.version), committed.iter().max());
    let m = svc.metrics().unwrap();
    assert_eq!(m.queries, 6);
    assert_eq!(m.query_count(deltagrad::session::QueryKind::Loss), 6);
    svc.shutdown().unwrap();
}

#[test]
fn query_path_restages_no_rows() {
    // the query-plane transfer budget: a Loss query uploads exactly two
    // parameter vectors (resident test + train evals) and downloads two
    // fused results — zero row bytes, zero re-staging, proven from the
    // per-plane metrics the worker keeps
    let dir = deltagrad::config::artifacts_dir().expect("make artifacts");
    let specs = deltagrad::config::parse_manifest(&dir.join("manifest.txt")).unwrap();
    let p = specs["small"].p as u64;
    let svc = ServiceHandle::spawn(small_cfg(BatchPolicy::default())).unwrap();
    let q = 3u64;
    for _ in 0..q {
        svc.query(Query::Loss).unwrap();
    }
    let m = svc.metrics().unwrap();
    assert_eq!(m.queries, q);
    assert_eq!(
        m.query_uploads,
        2 * q,
        "a loss query must upload exactly its two parameter vectors"
    );
    assert_eq!(
        m.query_upload_floats,
        2 * p * q,
        "query uploads must be parameter floats only — row re-staging detected"
    );
    assert_eq!(m.query_downloads, 2 * q, "one fused download per resident eval");
    // and none of it leaked into the edit-plane accounting
    assert_eq!(m.uploads, 0);
    assert_eq!(m.groups, 0);
    svc.shutdown().unwrap();
}

#[test]
fn query_queue_full_rejections_are_typed() {
    // the read lane's admission knob, independent of the write lane
    let svc = ServiceHandle::spawn(small_cfg(BatchPolicy {
        max_query_queue: 0,
        ..BatchPolicy::default()
    }))
    .unwrap();
    match svc.query(Query::Loss) {
        Err(Rejected::QueueFull { max_queue }) => assert_eq!(max_queue, 0),
        other => panic!("expected QueueFull, got {other:?}"),
    }
    // writes still admitted
    let rep = svc.update(Edit::delete_row(0)).unwrap();
    assert_eq!(rep.version, 1);
    svc.shutdown().unwrap();
}

/// The four Loss fields as raw bits, for bitwise-identity assertions.
fn loss_bits(r: &QueryResult) -> [u64; 4] {
    match r {
        QueryResult::Loss { test_loss, test_accuracy, train_loss, train_accuracy } => [
            test_loss.to_bits(),
            test_accuracy.to_bits(),
            train_loss.to_bits(),
            train_accuracy.to_bits(),
        ],
        other => panic!("wrong reply kind: {other:?}"),
    }
}

/// Poll metrics until every replica has replayed `replays` commits and
/// the pool's lag is zero (bounded; replicas drain their FIFO queues).
fn await_replicas_current(svc: &ServiceHandle, replays: u64) {
    let deadline = std::time::Instant::now() + Duration::from_secs(60);
    loop {
        let m = svc.metrics().unwrap();
        if m.reader_replays == replays && m.replica_lag == 0 {
            return;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "replicas never caught up: replays {} (want {replays}), lag {}",
            m.reader_replays,
            m.replica_lag
        );
        std::thread::sleep(Duration::from_millis(10));
    }
}

#[test]
fn reader_pool_answers_while_the_writer_commits() {
    // busy-writer smoke (R=1): with a reader pool the replica serves
    // every read concurrently with passes; the worker's between-pass
    // query lane is bypassed entirely
    let svc = ServiceHandle::spawn(ServiceConfig {
        readers: 1,
        ..small_cfg(BatchPolicy {
            max_group: 2,
            max_wait: Duration::from_millis(30),
            ..BatchPolicy::default()
        })
    })
    .unwrap();
    let mut edit_rxs = Vec::new();
    for i in 0..4 {
        edit_rxs.push(svc.update_async(Edit::delete_row(i)).unwrap());
        let rep = svc.query(Query::Loss).unwrap();
        match rep.result {
            QueryResult::Loss { test_accuracy, .. } => assert!(test_accuracy.is_finite()),
            other => panic!("wrong reply kind: {other:?}"),
        }
    }
    let mut committed = std::collections::BTreeSet::new();
    for rx in edit_rxs {
        committed.insert(rx.recv().unwrap().unwrap().version);
    }
    await_replicas_current(&svc, committed.len() as u64);
    let m = svc.metrics().unwrap();
    assert_eq!(m.readers, 1);
    assert_eq!(m.reader_queries, 4, "the replica must have served every read");
    assert_eq!(m.queries, 0, "the writer must not have served any read");
    svc.shutdown().unwrap();
}

#[test]
fn reader_pool_replies_stay_versioned_and_monotone() {
    // the R=0 snapshot-consistency contract survives R=2: every reply
    // names a committed version (or the initial 0) and per-client reply
    // versions are monotone — the delta-before-reply FIFO publication
    // argument, pinned end to end
    let svc = ServiceHandle::spawn(ServiceConfig {
        readers: 2,
        ..small_cfg(BatchPolicy {
            max_group: 2,
            max_wait: Duration::from_millis(30),
            ..BatchPolicy::default()
        })
    })
    .unwrap();
    let mut edit_rxs = Vec::new();
    let mut query_versions = Vec::new();
    for i in 0..6 {
        edit_rxs.push(svc.update_async(Edit::delete_row(i)).unwrap());
        query_versions.push(svc.query(Query::Loss).unwrap().version);
    }
    let mut committed: std::collections::BTreeSet<u64> = [0u64].into_iter().collect();
    for rx in edit_rxs {
        committed.insert(rx.recv().unwrap().unwrap().version);
    }
    for (i, v) in query_versions.iter().enumerate() {
        assert!(
            committed.contains(v),
            "query {i} was answered at v{v}, which the writer never committed \
             (committed: {committed:?})"
        );
    }
    assert!(
        query_versions.windows(2).all(|w| w[0] <= w[1]),
        "reply versions must be monotone: {query_versions:?}"
    );
    // quiescence: both replicas replay every commit, then lag is zero
    await_replicas_current(&svc, 2 * (committed.len() as u64 - 1));
    let m = svc.metrics().unwrap();
    assert_eq!(m.readers, 2);
    assert_eq!(m.reader_queries, 6);
    assert_eq!(m.replica_lag, 0);
    svc.shutdown().unwrap();
}

#[test]
fn replica_replay_is_bitwise_deterministic() {
    // a replica session replaying the writer's delta stream lands on
    // bitwise the same model as an offline session applying the same
    // edits — the determinism the read plane's correctness rests on
    let svc = ServiceHandle::spawn(ServiceConfig {
        readers: 1,
        ..small_cfg(BatchPolicy {
            max_group: 1,
            max_wait: Duration::from_millis(1),
            ..BatchPolicy::default()
        })
    })
    .unwrap();
    for i in 0..3 {
        svc.update(Edit::delete_row(i)).unwrap();
    }
    await_replicas_current(&svc, 3);
    let pool_rep = svc.query(Query::Loss).unwrap();
    assert_eq!(pool_rep.version, 3);
    let writer_snap = svc.snapshot().unwrap();
    svc.shutdown().unwrap();

    // offline: same recipe as small_cfg, same edits, no service at all
    let mut hp = HyperParams::for_dataset("small");
    hp.t = 40;
    hp.j0 = 6;
    hp.t0 = 5;
    let mut local = SessionBuilder::new("small")
        .seed(77)
        .n_train(Some(512))
        .n_test(Some(256))
        .hyper_params(hp)
        .build()
        .unwrap();
    for i in 0..3 {
        local.commit(Edit::delete_row(i)).unwrap();
    }
    let local_rep = local.query(&Query::Loss).unwrap();
    assert_eq!(
        loss_bits(&pool_rep.result),
        loss_bits(&local_rep.result),
        "replica replay diverged from the offline session"
    );
    let local_w: Vec<u32> = local.snapshot().unwrap().w.iter().map(|x| x.to_bits()).collect();
    let writer_w: Vec<u32> = writer_snap.w.iter().map(|x| x.to_bits()).collect();
    assert_eq!(local_w, writer_w, "writer diverged from the offline session");
}

#[test]
fn memo_cache_hit_is_bitwise_with_zero_transfers() {
    // the version-keyed memo cache: a repeated query between two commits
    // is answered from the handle — bitwise the same reply, ZERO device
    // transfers — and parameterization differences are cache misses
    let dir = deltagrad::config::artifacts_dir().expect("make artifacts");
    let specs = deltagrad::config::parse_manifest(&dir.join("manifest.txt")).unwrap();
    let da = specs["small"].da;
    let svc = ServiceHandle::spawn(ServiceConfig {
        query_cache: 8,
        ..small_cfg(BatchPolicy::default())
    })
    .unwrap();
    let first = svc.query(Query::Loss).unwrap();
    assert!(first.transfers.uploads > 0, "the miss executes on device");
    let second = svc.query(Query::Loss).unwrap();
    assert_eq!(loss_bits(&first.result), loss_bits(&second.result));
    assert_eq!(second.version, first.version);
    assert_eq!(
        second.transfers,
        TransferStats::default(),
        "a cache hit must move zero bytes"
    );
    // different params -> different key: x1 (miss), x1 (hit), x2 (miss)
    let mut x1 = vec![0.0f32; da];
    x1[da - 1] = 1.0;
    let mut x2 = x1.clone();
    x2[0] = 1.0;
    svc.query(Query::Predict { x: x1.clone() }).unwrap();
    svc.query(Query::Predict { x: x1 }).unwrap();
    svc.query(Query::Predict { x: x2 }).unwrap();
    let m = svc.metrics().unwrap();
    assert_eq!(m.cache_hits, 2);
    assert_eq!(m.cache_misses, 3);
    assert_eq!(m.cache_entries, 3);
    assert_eq!(m.cache_capacity, 8);
    assert_eq!(m.queries, 3, "hits must never reach the worker");
    svc.shutdown().unwrap();
}

#[test]
fn memo_cache_invalidates_across_commits() {
    // commit-time invalidation: an entry memoized at version v must not
    // answer a query after version v+1 committed
    let svc = ServiceHandle::spawn(ServiceConfig {
        query_cache: 8,
        ..small_cfg(BatchPolicy {
            max_group: 1,
            max_wait: Duration::from_millis(1),
            ..BatchPolicy::default()
        })
    })
    .unwrap();
    let before = svc.query(Query::Loss).unwrap();
    assert_eq!(before.version, 0);
    svc.update(Edit::delete_row(0)).unwrap();
    let after = svc.query(Query::Loss).unwrap();
    assert_eq!(after.version, 1, "a commit must invalidate version-0 entries");
    assert!(after.transfers.uploads > 0, "the post-commit read must re-execute");
    let m = svc.metrics().unwrap();
    assert_eq!(m.cache_hits, 0);
    assert_eq!(m.cache_misses, 2);
    assert_eq!(m.cache_entries, 1);
    svc.shutdown().unwrap();
}

#[test]
fn replicas_spawn_from_the_writers_artifact() {
    // PR 6 gap closed: replicas warm-restore from the artifact the
    // worker saves at spawn instead of retraining from the recipe —
    // every reader reports restored=1 and still serves correct reads
    let svc = ServiceHandle::spawn(ServiceConfig {
        readers: 2,
        ..small_cfg(BatchPolicy {
            max_group: 1,
            max_wait: Duration::from_millis(1),
            ..BatchPolicy::default()
        })
    })
    .unwrap();
    // readers restore asynchronously after the worker hands them the
    // artifact path; poll until both report in
    let deadline = std::time::Instant::now() + Duration::from_secs(60);
    loop {
        let m = svc.metrics().unwrap();
        if m.reader_restores == 2 {
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "replicas never restored from the spawn artifact: restores {}",
            m.reader_restores
        );
        std::thread::sleep(Duration::from_millis(10));
    }
    // a restored replica serves reads and replays commits like before
    svc.update(Edit::delete_row(0)).unwrap();
    await_replicas_current(&svc, 2);
    let rep = svc.query(Query::Loss).unwrap();
    assert_eq!(rep.version, 1);
    match rep.result {
        QueryResult::Loss { test_accuracy, .. } => assert!(test_accuracy.is_finite()),
        other => panic!("wrong reply kind: {other:?}"),
    }
    svc.shutdown().unwrap();
}

#[test]
fn checkpoint_every_commit_writes_loadable_store_artifacts() {
    use deltagrad::session::artifact::Artifact;

    let store = std::env::temp_dir()
        .join(format!("deltagrad-test-ckpt-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&store);
    let svc = ServiceHandle::spawn(ServiceConfig {
        checkpoint_every: 1,
        checkpoint_dir: Some(store.clone()),
        ..small_cfg(BatchPolicy {
            max_group: 1,
            max_wait: Duration::from_millis(1),
            ..BatchPolicy::default()
        })
    })
    .unwrap();
    svc.update(Edit::delete_row(0)).unwrap();
    svc.update(Edit::delete_row(1)).unwrap();
    let m = svc.metrics().unwrap();
    assert_eq!(m.checkpoints, 2, "K=1 must checkpoint every commit");
    assert!(m.checkpoint_seconds > 0.0);
    svc.shutdown().unwrap();

    // the store holds one content-addressed file per version, and each
    // round-trips through the typed loader
    let mut versions = Vec::new();
    for entry in std::fs::read_dir(&store).unwrap() {
        let path = entry.unwrap().path();
        assert_eq!(path.extension().and_then(|e| e.to_str()), Some("dgar"));
        versions.push(Artifact::load(&path).unwrap().version);
    }
    versions.sort_unstable();
    assert_eq!(versions, vec![1, 2]);
    std::fs::remove_dir_all(&store).unwrap();
}

#[test]
fn queue_full_rejections_are_typed() {
    // direct check of the typed error surface (the property test in
    // batcher.rs covers the bound itself): max_queue = 0 rejects every
    // arrival deterministically, without touching the worker's session
    let svc = ServiceHandle::spawn(small_cfg(BatchPolicy {
        max_group: 8,
        max_wait: Duration::from_millis(5),
        max_queue: 0,
        ..BatchPolicy::default()
    }))
    .unwrap();
    match svc.update(Edit::delete_row(0)) {
        Err(Rejected::QueueFull { max_queue }) => assert_eq!(max_queue, 0),
        other => panic!("expected QueueFull, got {other:?}"),
    }
    // snapshot still served; nothing was committed
    let snap = svc.snapshot().unwrap();
    assert_eq!(snap.version, 0);
    assert_eq!(snap.n_train, 512);
    svc.shutdown().unwrap();
}

#[test]
fn stale_lineage_guard_refuses_fresh_durable_serve() {
    // a prior lineage already checkpointed into the store: serving
    // FRESH (version counter back to 0) with durability on would
    // interleave a second history into the one those checkpoints anchor
    let store = std::env::temp_dir()
        .join(format!("deltagrad-test-guard-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&store);
    let mut hp = HyperParams::for_dataset("small");
    hp.t = 40;
    hp.j0 = 6;
    hp.t0 = 5;
    let mut prior = SessionBuilder::new("small")
        .seed(77)
        .n_train(Some(512))
        .n_test(Some(256))
        .hyper_params(hp)
        .build()
        .unwrap();
    prior.commit(Edit::delete_row(0)).unwrap();
    deltagrad::session::artifact::save_to_store(&prior, &store).unwrap();
    let policy = || BatchPolicy {
        max_group: 1,
        max_wait: Duration::from_millis(1),
        ..BatchPolicy::default()
    };

    // the guard kills the worker before it trains anything; the handle
    // sees a dead service and shutdown surfaces the actionable error
    let svc = ServiceHandle::spawn(ServiceConfig {
        wal: true,
        checkpoint_dir: Some(store.clone()),
        ..small_cfg(policy())
    })
    .unwrap();
    match svc.update(Edit::delete_row(1)) {
        Err(Rejected::Stopped) => {}
        other => panic!("expected the lineage guard to stop the worker, got {other:?}"),
    }
    let err = format!("{:#}", svc.shutdown().unwrap_err());
    assert!(err.contains("already holds"), "guard must explain the refusal: {err}");
    assert!(err.contains("--store-fresh"), "guard must name the override: {err}");
    assert!(err.contains("--restore-latest"), "guard must name the continuation: {err}");

    // --restore-latest continues the stored lineage instead
    let svc = ServiceHandle::spawn(ServiceConfig {
        wal: true,
        restore_latest: true,
        checkpoint_dir: Some(store.clone()),
        ..small_cfg(policy())
    })
    .unwrap();
    let snap = svc.snapshot().unwrap();
    assert_eq!(snap.version, 1, "restore-latest must resume at the checkpoint's version");
    assert_eq!(svc.update(Edit::delete_row(1)).unwrap().version, 2);
    svc.shutdown().unwrap();

    // --store-fresh overrides the guard deliberately
    let svc = ServiceHandle::spawn(ServiceConfig {
        wal: true,
        store_fresh: true,
        checkpoint_dir: Some(store.clone()),
        ..small_cfg(policy())
    })
    .unwrap();
    assert_eq!(svc.update(Edit::delete_row(1)).unwrap().version, 1);
    svc.shutdown().unwrap();
    let _ = std::fs::remove_dir_all(&store);
}

#[test]
fn wal_group_commit_shares_fsyncs_across_a_burst() {
    // a burst of updates queued while the worker is still training must
    // drain as one group-commit sweep: every commit journals its record
    // with append_nosync, ONE fsync lands before any ack — so the sync
    // count stays strictly below the record count
    let store = std::env::temp_dir()
        .join(format!("deltagrad-test-groupfsync-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&store);
    let svc = ServiceHandle::spawn(ServiceConfig {
        wal: true,
        checkpoint_dir: Some(store.clone()),
        query_cache: 8,
        query_cache_bytes: 1 << 20,
        ..small_cfg(BatchPolicy {
            max_group: 1,
            max_wait: Duration::from_millis(1),
            ..BatchPolicy::default()
        })
    })
    .unwrap();
    // enqueue the whole burst before the initial training finishes
    let rxs: Vec<_> =
        (0..5).map(|i| svc.update_async(Edit::delete_row(i)).unwrap()).collect();
    for rx in rxs {
        rx.recv().unwrap().unwrap();
    }
    let m = svc.metrics().unwrap();
    assert_eq!(m.wal_records, 5, "every commit journals exactly one record");
    assert!(m.wal_syncs >= 1, "an acked burst implies at least one fsync");
    assert!(
        m.wal_syncs < m.wal_records,
        "a burst must amortize fsyncs across its commits (got {} syncs / {} records)",
        m.wal_syncs,
        m.wal_records
    );

    // the byte-budgeted memo cache reports its footprint through the
    // same metrics surface
    svc.query(Query::Loss).unwrap();
    svc.query(Query::Loss).unwrap();
    let m = svc.metrics().unwrap();
    assert_eq!(m.cache_byte_budget, 1 << 20);
    assert!(m.cache_bytes > 0, "a memoized entry must account its bytes");
    assert_eq!(m.cache_hits, 1);
    svc.shutdown().unwrap();
    let _ = std::fs::remove_dir_all(&store);
}
