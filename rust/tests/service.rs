//! Coordinator integration: the unlearning service end to end.
//! Requires `make artifacts`.

use std::time::Duration;

use deltagrad::config::HyperParams;
use deltagrad::coordinator::{BatchPolicy, ServiceConfig, ServiceHandle};
use deltagrad::deltagrad::online::Request;

fn small_cfg(policy: BatchPolicy) -> ServiceConfig {
    let mut hp = HyperParams::for_dataset("small");
    hp.t = 40;
    hp.j0 = 6;
    hp.t0 = 5;
    ServiceConfig {
        model: "small".into(),
        seed: 77,
        n_train: Some(512),
        n_test: Some(256),
        hp,
        policy,
    }
}

#[test]
fn serves_sequential_deletions() {
    let svc = ServiceHandle::spawn(small_cfg(BatchPolicy {
        max_group: 1,
        max_wait: Duration::from_millis(1),
    }))
    .unwrap();
    let snap0 = svc.snapshot().unwrap();
    assert_eq!(snap0.version, 0);
    assert_eq!(snap0.n_train, 512);
    assert!(snap0.test_accuracy > 0.5, "initial acc {}", snap0.test_accuracy);

    for i in 0..3 {
        let rep = svc.update(Request::Delete(i)).unwrap();
        assert_eq!(rep.version, (i + 1) as u64);
        assert_eq!(rep.group_size, 1);
        assert!(rep.n_exact > 0);
    }
    let snap = svc.snapshot().unwrap();
    assert_eq!(snap.version, 3);
    assert_eq!(snap.n_train, 509);
    assert!(snap.test_accuracy > 0.5);

    let m = svc.metrics().unwrap();
    assert_eq!(m.requests, 3);
    assert_eq!(m.groups, 3);
    svc.shutdown().unwrap();
}

#[test]
fn group_commit_coalesces_concurrent_requests() {
    let svc = ServiceHandle::spawn(small_cfg(BatchPolicy {
        max_group: 8,
        max_wait: Duration::from_millis(150),
    }))
    .unwrap();
    // enqueue 5 requests quickly without waiting
    let rxs: Vec<_> = (10..15)
        .map(|i| svc.update_async(Request::Delete(i)).unwrap())
        .collect();
    let mut versions = Vec::new();
    let mut group_sizes = Vec::new();
    for rx in rxs {
        let rep = rx.recv().unwrap().unwrap();
        versions.push(rep.version);
        group_sizes.push(rep.group_size);
    }
    // all five should have been committed together (single version bump)
    assert!(
        group_sizes.iter().all(|&g| g == 5),
        "expected one group of 5, got {group_sizes:?}"
    );
    assert!(versions.iter().all(|&v| v == versions[0]));
    let m = svc.metrics().unwrap();
    assert_eq!(m.requests, 5);
    assert_eq!(m.groups, 1);
    assert!((m.mean_group_size() - 5.0).abs() < 1e-9);
    svc.shutdown().unwrap();
}

#[test]
fn rejects_double_delete_but_keeps_serving() {
    let svc = ServiceHandle::spawn(small_cfg(BatchPolicy {
        max_group: 1,
        max_wait: Duration::from_millis(1),
    }))
    .unwrap();
    svc.update(Request::Delete(0)).unwrap();
    let err = svc.update(Request::Delete(0));
    assert!(err.is_err(), "double delete must be rejected");
    // the service must still be healthy
    let rep = svc.update(Request::Delete(1)).unwrap();
    assert!(rep.version >= 2);
    svc.shutdown().unwrap();
}

#[test]
fn addition_requests_grow_the_dataset() {
    let svc = ServiceHandle::spawn(small_cfg(BatchPolicy {
        max_group: 1,
        max_wait: Duration::from_millis(1),
    }))
    .unwrap();
    let snap0 = svc.snapshot().unwrap();
    // fabricate a plausible sample: zeros with bias column
    let da = snap0.w.len() / 3; // small: k=3
    let mut x = vec![0.0f32; da];
    x[da - 1] = 1.0;
    let rep = svc.update(Request::Add(x, 1)).unwrap();
    assert_eq!(rep.version, 1);
    let snap = svc.snapshot().unwrap();
    assert_eq!(snap.n_train, 513);
    svc.shutdown().unwrap();
}
