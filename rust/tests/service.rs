//! Coordinator integration: the unlearning service end to end — both
//! planes (edits through the group-commit batcher, typed read queries
//! answered between passes). Requires `make artifacts`.

use std::time::Duration;

use deltagrad::config::HyperParams;
use deltagrad::coordinator::{BatchPolicy, Rejected, ServiceConfig, ServiceHandle};
use deltagrad::session::{Edit, Query, QueryResult};

fn small_cfg(policy: BatchPolicy) -> ServiceConfig {
    let mut hp = HyperParams::for_dataset("small");
    hp.t = 40;
    hp.j0 = 6;
    hp.t0 = 5;
    ServiceConfig {
        model: "small".into(),
        seed: 77,
        n_train: Some(512),
        n_test: Some(256),
        hp,
        policy,
    }
}

#[test]
fn serves_sequential_deletions() {
    let svc = ServiceHandle::spawn(small_cfg(BatchPolicy {
        max_group: 1,
        max_wait: Duration::from_millis(1),
        ..BatchPolicy::default()
    }))
    .unwrap();
    let snap0 = svc.snapshot().unwrap();
    assert_eq!(snap0.version, 0);
    assert_eq!(snap0.n_train, 512);
    assert!(snap0.test_accuracy > 0.5, "initial acc {}", snap0.test_accuracy);

    for i in 0..3 {
        let rep = svc.update(Edit::delete_row(i)).unwrap();
        assert_eq!(rep.version, (i + 1) as u64);
        assert_eq!(rep.group_size, 1);
        assert!(rep.n_exact > 0);
    }
    let snap = svc.snapshot().unwrap();
    assert_eq!(snap.version, 3);
    assert_eq!(snap.n_train, 509);
    assert!(snap.test_accuracy > 0.5);

    let m = svc.metrics().unwrap();
    assert_eq!(m.requests, 3);
    assert_eq!(m.groups, 3);
    assert_eq!(m.deletes, 3);
    assert_eq!(m.adds, 0);
    svc.shutdown().unwrap();
}

#[test]
fn group_commit_coalesces_concurrent_requests() {
    let svc = ServiceHandle::spawn(small_cfg(BatchPolicy {
        max_group: 8,
        max_wait: Duration::from_millis(150),
        ..BatchPolicy::default()
    }))
    .unwrap();
    // enqueue 5 requests quickly without waiting
    let rxs: Vec<_> = (10..15)
        .map(|i| svc.update_async(Edit::delete_row(i)).unwrap())
        .collect();
    let mut versions = Vec::new();
    let mut group_sizes = Vec::new();
    for rx in rxs {
        let rep = rx.recv().unwrap().unwrap();
        versions.push(rep.version);
        group_sizes.push(rep.group_size);
    }
    // all five should have been committed together (single version bump)
    assert!(
        group_sizes.iter().all(|&g| g == 5),
        "expected one group of 5, got {group_sizes:?}"
    );
    assert!(versions.iter().all(|&v| v == versions[0]));
    let m = svc.metrics().unwrap();
    assert_eq!(m.requests, 5);
    assert_eq!(m.groups, 1);
    assert!((m.mean_group_size() - 5.0).abs() < 1e-9);
    svc.shutdown().unwrap();
}

#[test]
fn committed_group_uploads_delta_rows_exactly_once() {
    // transfer-accounting regression (docs/PERFORMANCE.md budget): one
    // committed group of k deletes ships
    //   3·⌈k/chunk_small⌉ buffers  (the delta rows, once per PASS)
    //   + T                        (one parameter upload per iteration)
    //   + the touched removal-mask chunks (flipped in place post-pass)
    // and NOTHING else — the base dataset and test set are resident.
    // shape info straight from the manifest (no second PJRT client)
    let dir = deltagrad::config::artifacts_dir().expect("make artifacts");
    let specs = deltagrad::config::parse_manifest(&dir.join("manifest.txt")).unwrap();
    let spec = specs["small"].clone();
    let cfg = small_cfg(BatchPolicy {
        max_group: 8,
        max_wait: Duration::from_millis(150),
        ..BatchPolicy::default()
    });
    let hp_t = cfg.hp.t;
    let svc = ServiceHandle::spawn(cfg).unwrap();
    // k deletes, all inside the first staged chunk -> exactly one mask
    // re-upload when the commit flips them
    let k = 3usize;
    let rxs: Vec<_> = (0..k)
        .map(|i| svc.update_async(Edit::delete_row(i)).unwrap())
        .collect();
    for rx in rxs {
        let rep = rx.recv().unwrap().unwrap();
        assert_eq!(rep.group_size, k, "test assumes one group commit");
    }
    let m = svc.metrics().unwrap();
    let delta_groups = k.div_ceil(spec.chunk_small);
    let touched_chunks = 1; // rows 0..k live in staged chunk 0 (k << chunk)
    assert!(k < spec.chunk, "victims must share one chunk for this budget");
    let expected = (3 * delta_groups + hp_t + touched_chunks) as u64;
    assert_eq!(
        m.uploads, expected,
        "committed group upload budget changed: got {}, expected \
         3*{delta_groups} + {hp_t} + {touched_chunks}",
        m.uploads
    );
    // fused-reduction download budget: the group's signed delta gradient
    // downloads once per iteration, the current-data gradient once per
    // exact iteration — never one literal per chunk
    assert_eq!(
        m.downloads,
        hp_t as u64 + m.exact_iters,
        "committed group download budget changed"
    );
    // exactly one pass-worth of executions was recorded
    assert_eq!(m.groups, 1);
    svc.shutdown().unwrap();
}

#[test]
fn rejects_double_delete_but_keeps_serving() {
    let svc = ServiceHandle::spawn(small_cfg(BatchPolicy {
        max_group: 1,
        max_wait: Duration::from_millis(1),
        ..BatchPolicy::default()
    }))
    .unwrap();
    svc.update(Edit::delete_row(0)).unwrap();
    let err = svc.update(Edit::delete_row(0));
    match err {
        Err(Rejected::Failed(msg)) => assert!(msg.contains("already deleted"), "{msg}"),
        other => panic!("double delete must be rejected as Failed, got {other:?}"),
    }
    // the service must still be healthy
    let rep = svc.update(Edit::delete_row(1)).unwrap();
    assert!(rep.version >= 2);
    svc.shutdown().unwrap();
}

#[test]
fn addition_requests_grow_the_dataset() {
    let svc = ServiceHandle::spawn(small_cfg(BatchPolicy {
        max_group: 1,
        max_wait: Duration::from_millis(1),
        ..BatchPolicy::default()
    }))
    .unwrap();
    let snap0 = svc.snapshot().unwrap();
    // fabricate a plausible sample: zeros with bias column
    let k = 3; // small: k=3
    let da = snap0.w.len() / k;
    let mut x = vec![0.0f32; da];
    x[da - 1] = 1.0;
    let rep = svc.update(Edit::add_row(x, 1, k)).unwrap();
    assert_eq!(rep.version, 1);
    let snap = svc.snapshot().unwrap();
    assert_eq!(snap.n_train, 513);
    let m = svc.metrics().unwrap();
    assert_eq!(m.adds, 1);
    svc.shutdown().unwrap();
}

#[test]
fn interleaved_queries_carry_committed_versions() {
    // the snapshot-consistency contract: every QueryReply.version is a
    // version the worker actually committed (or the initial 0), replies
    // are monotone in request order, and reads never block on the write
    // batcher's max_wait
    let svc = ServiceHandle::spawn(small_cfg(BatchPolicy {
        max_group: 2,
        max_wait: Duration::from_millis(30),
        ..BatchPolicy::default()
    }))
    .unwrap();
    let mut edit_rxs = Vec::new();
    let mut query_versions = Vec::new();
    for i in 0..6 {
        edit_rxs.push(svc.update_async(Edit::delete_row(i)).unwrap());
        let rep = svc.query(Query::Loss).unwrap();
        match rep.result {
            QueryResult::Loss { test_accuracy, .. } => {
                assert!(test_accuracy.is_finite());
            }
            other => panic!("wrong reply kind: {other:?}"),
        }
        query_versions.push(rep.version);
    }
    // the set of versions the worker reported committing
    let mut committed: std::collections::BTreeSet<u64> = [0u64].into_iter().collect();
    for rx in edit_rxs {
        committed.insert(rx.recv().unwrap().unwrap().version);
    }
    for (i, v) in query_versions.iter().enumerate() {
        assert!(
            committed.contains(v),
            "query {i} was answered at v{v}, which the worker never committed \
             (committed: {committed:?})"
        );
    }
    assert!(
        query_versions.windows(2).all(|w| w[0] <= w[1]),
        "reply versions must be monotone: {query_versions:?}"
    );
    // the final snapshot is the largest committed version
    let snap = svc.snapshot().unwrap();
    assert_eq!(Some(&snap.version), committed.iter().max());
    let m = svc.metrics().unwrap();
    assert_eq!(m.queries, 6);
    assert_eq!(m.query_count(deltagrad::session::QueryKind::Loss), 6);
    svc.shutdown().unwrap();
}

#[test]
fn query_path_restages_no_rows() {
    // the query-plane transfer budget: a Loss query uploads exactly two
    // parameter vectors (resident test + train evals) and downloads two
    // fused results — zero row bytes, zero re-staging, proven from the
    // per-plane metrics the worker keeps
    let dir = deltagrad::config::artifacts_dir().expect("make artifacts");
    let specs = deltagrad::config::parse_manifest(&dir.join("manifest.txt")).unwrap();
    let p = specs["small"].p as u64;
    let svc = ServiceHandle::spawn(small_cfg(BatchPolicy::default())).unwrap();
    let q = 3u64;
    for _ in 0..q {
        svc.query(Query::Loss).unwrap();
    }
    let m = svc.metrics().unwrap();
    assert_eq!(m.queries, q);
    assert_eq!(
        m.query_uploads,
        2 * q,
        "a loss query must upload exactly its two parameter vectors"
    );
    assert_eq!(
        m.query_upload_floats,
        2 * p * q,
        "query uploads must be parameter floats only — row re-staging detected"
    );
    assert_eq!(m.query_downloads, 2 * q, "one fused download per resident eval");
    // and none of it leaked into the edit-plane accounting
    assert_eq!(m.uploads, 0);
    assert_eq!(m.groups, 0);
    svc.shutdown().unwrap();
}

#[test]
fn query_queue_full_rejections_are_typed() {
    // the read lane's admission knob, independent of the write lane
    let svc = ServiceHandle::spawn(small_cfg(BatchPolicy {
        max_query_queue: 0,
        ..BatchPolicy::default()
    }))
    .unwrap();
    match svc.query(Query::Loss) {
        Err(Rejected::QueueFull { max_queue }) => assert_eq!(max_queue, 0),
        other => panic!("expected QueueFull, got {other:?}"),
    }
    // writes still admitted
    let rep = svc.update(Edit::delete_row(0)).unwrap();
    assert_eq!(rep.version, 1);
    svc.shutdown().unwrap();
}

#[test]
fn queue_full_rejections_are_typed() {
    // direct check of the typed error surface (the property test in
    // batcher.rs covers the bound itself): max_queue = 0 rejects every
    // arrival deterministically, without touching the worker's session
    let svc = ServiceHandle::spawn(small_cfg(BatchPolicy {
        max_group: 8,
        max_wait: Duration::from_millis(5),
        max_queue: 0,
        ..BatchPolicy::default()
    }))
    .unwrap();
    match svc.update(Edit::delete_row(0)) {
        Err(Rejected::QueueFull { max_queue }) => assert_eq!(max_queue, 0),
        other => panic!("expected QueueFull, got {other:?}"),
    }
    // snapshot still served; nothing was committed
    let snap = svc.snapshot().unwrap();
    assert_eq!(snap.version, 0);
    assert_eq!(snap.n_train, 512);
    svc.shutdown().unwrap();
}
