//! Algorithm 3 (online deletion/addition) integration tests.
//! Requires `make artifacts`.

use deltagrad::config::HyperParams;
use deltagrad::data::{synth, IndexSet};
use deltagrad::deltagrad::online::{OnlineState, Request};
use deltagrad::runtime::Engine;
use deltagrad::train::{self, TrainOpts};
use deltagrad::util::vecmath::dist2;

fn setup() -> (
    Engine,
    std::rc::Rc<deltagrad::ModelExes>,
    deltagrad::Dataset,
    deltagrad::Dataset,
    HyperParams,
    Vec<f32>,
    deltagrad::train::Trajectory,
) {
    let mut eng = Engine::open_default().expect("make artifacts");
    let exes = eng.model("small").unwrap();
    let spec = exes.spec.clone();
    let (train_ds, test_ds) = synth::train_test_for_spec(&spec, 33, Some(640), Some(256));
    let mut hp = HyperParams::for_dataset("small");
    hp.t = 50;
    hp.j0 = 8;
    hp.t0 = 5;
    let out = train::train(&exes, &eng.rt, &train_ds, &TrainOpts::full(&hp, &IndexSet::empty()))
        .unwrap();
    (eng, exes, train_ds, test_ds, hp, out.w, out.traj.unwrap())
}

#[test]
fn sequential_deletions_track_basel() {
    let (eng, exes, train_ds, _test, hp, _w, traj) = setup();
    let mut state =
        OnlineState::new(&exes, &eng.rt, train_ds.clone(), traj, hp.clone()).unwrap();
    let victims = [3usize, 77, 200, 401, 555];
    let mut w_i = Vec::new();
    for &v in &victims {
        let out = state.apply(&exes, &eng.rt, Request::Delete(v)).unwrap();
        w_i = out.w;
        assert!(out.n_approx > 0, "online pass should approximate");
    }
    assert_eq!(state.n_current(), train_ds.n - victims.len());
    // BaseL on the final remaining set
    let removed = IndexSet::from_vec(victims.to_vec());
    let basel = train::train(&exes, &eng.rt, &train_ds, &TrainOpts::full(&hp, &removed)).unwrap();
    let d = dist2(&w_i, &basel.w);
    let moved = dist2(&state.traj.ws[0], &basel.w).max(1e-12);
    assert!(
        d < 0.5 * moved.max(dist2(&basel.w, &basel.w) + 1e-3),
        "online drift {d:.3e} too large vs scale {moved:.3e}"
    );
}

#[test]
fn online_matches_batch_for_single_request() {
    // one online deletion == one batch deletion (same trajectory)
    let (eng, exes, train_ds, _test, hp, _w, traj) = setup();
    let victim = 123usize;
    let mut state =
        OnlineState::new(&exes, &eng.rt, train_ds.clone(), traj.clone(), hp.clone()).unwrap();
    let online = state.apply(&exes, &eng.rt, Request::Delete(victim)).unwrap();
    let removed = IndexSet::from_vec(vec![victim]);
    let batch =
        deltagrad::deltagrad::batch::delete_gd(&exes, &eng.rt, &train_ds, &traj, &hp, &removed)
            .unwrap();
    let d = dist2(&online.w, &batch.w);
    let scale = deltagrad::util::vecmath::norm2(&batch.w).max(1e-12);
    assert!(d / scale < 1e-4, "online vs batch mismatch {d:.3e} (scale {scale:.3e})");
}

#[test]
fn online_addition_then_deletion_roundtrip_stays_close() {
    let (eng, exes, train_ds, _test, hp, w_full, traj) = setup();
    let spec = exes.spec.clone();
    let mut state = OnlineState::new(&exes, &eng.rt, train_ds.clone(), traj, hp.clone()).unwrap();
    // add two fresh samples, then delete one original
    let adds = synth::addition_rows(&spec, 5, 2);
    for i in 0..2 {
        state
            .apply(&exes, &eng.rt, Request::Add(adds.row(i).to_vec(), adds.y[i]))
            .unwrap();
    }
    let out = state.apply(&exes, &eng.rt, Request::Delete(10)).unwrap();
    assert_eq!(state.n_current(), train_ds.n + 2 - 1);
    // the model should not have wandered far from the original optimum
    let drift = dist2(&out.w, &w_full);
    assert!(drift < 0.5, "online drift {drift} implausibly large");
    // and BaseL on the materialized current dataset should agree
    let current = state.current_dataset();
    assert_eq!(current.n, state.n_current());
    let basel =
        train::train(&exes, &eng.rt, &current, &TrainOpts::full(&hp, &IndexSet::empty())).unwrap();
    let gap = dist2(&out.w, &basel.w);
    let moved = dist2(&w_full, &basel.w).max(1e-12);
    assert!(gap < moved, "online ({gap:.2e}) should beat the stale model ({moved:.2e})");
}

#[test]
fn group_apply_equals_sequential_dataset_state() {
    let (eng, exes, train_ds, _test, hp, _w, traj) = setup();
    let mut state =
        OnlineState::new(&exes, &eng.rt, train_ds.clone(), traj, hp.clone()).unwrap();
    let reqs = vec![Request::Delete(1), Request::Delete(2), Request::Delete(3)];
    let out = state.apply_group(&exes, &eng.rt, &reqs).unwrap();
    assert_eq!(state.n_current(), train_ds.n - 3);
    assert!(out.n_exact > 0 && out.n_approx > 0);
    // double-delete in one group must be rejected atomically
    let bad = vec![Request::Delete(4), Request::Delete(4)];
    assert!(state.apply_group(&exes, &eng.rt, &bad).is_err());
    assert_eq!(state.n_current(), train_ds.n - 3, "failed group must not commit");
}
