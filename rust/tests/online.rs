//! Algorithm 3 (online deletion/addition) integration tests, driven
//! through `session.commit`. Requires `make artifacts`.

use deltagrad::config::HyperParams;
use deltagrad::data::{synth, IndexSet};
use deltagrad::runtime::Engine;
use deltagrad::session::{Edit, Session, SessionBuilder};
use deltagrad::util::vecmath::dist2;

fn setup() -> (Engine, Session) {
    let mut eng = Engine::open_default().expect("make artifacts");
    let spec = eng.spec("small").unwrap().clone();
    let (train_ds, test_ds) = synth::train_test_for_spec(&spec, 33, Some(640), Some(256));
    let mut hp = HyperParams::for_dataset("small");
    hp.t = 50;
    hp.j0 = 8;
    hp.t0 = 5;
    let session = SessionBuilder::new("small")
        .hyper_params(hp)
        .datasets(train_ds, test_ds)
        .build_in(&mut eng)
        .unwrap();
    (eng, session)
}

#[test]
fn sequential_deletions_track_basel() {
    let (_eng, mut session) = setup();
    let n0 = session.train_dataset().n;
    let victims = [3usize, 77, 200, 401, 555];
    let mut w_i = Vec::new();
    for &v in &victims {
        let c = session.commit(Edit::delete_row(v)).unwrap();
        w_i = c.out.w;
        assert!(c.out.n_approx > 0, "online pass should approximate");
    }
    assert_eq!(session.n_current(), n0 - victims.len());
    assert_eq!(session.version(), victims.len() as u64);
    // BaseL on the final remaining set: an empty edit on the committed
    // session retrains exactly the current dataset
    let basel = session.baseline(&Edit::Delete(IndexSet::empty())).unwrap();
    let d = dist2(&w_i, &basel.w);
    let moved = dist2(&session.trajectory().ws[0], &basel.w).max(1e-12);
    assert!(
        d < 0.5 * moved.max(1e-3),
        "online drift {d:.3e} too large vs scale {moved:.3e}"
    );
}

#[test]
fn online_commit_matches_batch_preview_for_single_edit() {
    // one committed deletion ~= one speculative batch deletion (same
    // trajectory, different but convergent arithmetic)
    let (_eng, mut session) = setup();
    let victim = 123usize;
    let edit = Edit::delete_row(victim);
    let pv = session.preview(&edit).unwrap();
    let c = session.commit(edit).unwrap();
    let d = dist2(&c.out.w, &pv.out.w);
    let scale = deltagrad::util::vecmath::norm2(&pv.out.w).max(1e-12);
    assert!(d / scale < 1e-4, "commit vs preview mismatch {d:.3e} (scale {scale:.3e})");
}

#[test]
fn online_addition_then_deletion_roundtrip_stays_close() {
    let (_eng, mut session) = setup();
    let spec = session.spec().clone();
    let n0 = session.train_dataset().n;
    let w_full = session.w().to_vec();
    // add two fresh samples, then delete one original
    let adds = synth::addition_rows(&spec, 5, 2);
    for i in 0..2 {
        session
            .commit(Edit::add_row(adds.row(i).to_vec(), adds.y[i], spec.k))
            .unwrap();
    }
    let out = session.commit(Edit::delete_row(10)).unwrap();
    assert_eq!(session.n_current(), n0 + 2 - 1);
    // the model should not have wandered far from the original optimum
    let drift = dist2(&out.out.w, &w_full);
    assert!(drift < 0.5, "online drift {drift} implausibly large");
    // and BaseL on the materialized current dataset should agree
    let current = session.current_dataset();
    assert_eq!(current.n, session.n_current());
    let basel = session.baseline(&Edit::Delete(IndexSet::empty())).unwrap();
    let gap = dist2(&out.out.w, &basel.w);
    let moved = dist2(&w_full, &basel.w).max(1e-12);
    assert!(gap < moved, "online ({gap:.2e}) should beat the stale model ({moved:.2e})");
}

#[test]
fn group_commit_equals_sequential_dataset_state() {
    let (_eng, mut session) = setup();
    let n0 = session.train_dataset().n;
    let edit = Edit::Delete(IndexSet::from_vec(vec![1, 2, 3]));
    let c = session.commit(edit).unwrap();
    assert_eq!(session.n_current(), n0 - 3);
    assert!(c.out.n_exact > 0 && c.out.n_approx > 0);
    // double-delete in one group must be rejected atomically
    let bad = Edit::group(vec![Edit::delete_row(4), Edit::delete_row(4)]);
    assert!(session.commit(bad).is_err());
    assert_eq!(session.n_current(), n0 - 3, "failed group must not commit");
    assert_eq!(session.version(), 1, "failed group must not bump the version");
    // deleting an already-removed row must also fail atomically
    assert!(session.commit(Edit::delete_row(2)).is_err());
    assert_eq!(session.n_current(), n0 - 3);
    // an empty edit must not burn a pass or bump the version
    assert!(session.commit(Edit::Delete(IndexSet::empty())).is_err());
    assert_eq!(session.version(), 1);
}
