//! Session API equivalence tests (requires `make artifacts`).
//!
//! The Session redesign claims preview/commit are pure API re-plumbing
//! over the pinned algorithm cores: same floats in, same floats out.
//! These tests pin that down:
//!  * `preview` of a Delete edit is BITWISE identical to the old
//!    `delete_gd` free function on the seed workload;
//!  * `commit` of a single-kind group is BITWISE identical to the
//!    pre-redesign `OnlineState::apply_group` loop (kept as a
//!    seed-shape reference in `testing::baseline`), including the
//!    rewritten trajectory and across the double-buffered generations;
//!    MIXED groups now fuse the signed delta chain (one download per
//!    iteration) and pin at 1e-5 instead;
//!  * tail compaction caps the committed tail at ⌈tail/chunk⌉ launches
//!    without changing floats beyond reduction order;
//!  * interleaved previews from one base perturb neither each other nor
//!    the committed state;
//!  * GD vs SGD auto-selection follows `hp.batch`, and the SGD preview
//!    matches the old `delete_sgd`;
//!  * the per-pass upload AND download budgets of the staged-context
//!    layer hold through the new API (preview pays no base re-staging,
//!    one fused download per gradient call);
//!  * the cross-pass row cache serves repeated previews of one index
//!    set (folds, leave-outs) without re-staging, across commits.

#![allow(deprecated)]

use deltagrad::config::HyperParams;
use deltagrad::data::{sample_removal, synth, IndexSet};
use deltagrad::deltagrad::batch;
use deltagrad::runtime::Engine;
use deltagrad::session::{Edit, PassMode, SessionBuilder};
use deltagrad::util::Rng;

fn engine() -> Engine {
    Engine::open_default().expect("run `make artifacts` first")
}

fn small_hp() -> HyperParams {
    let mut hp = HyperParams::for_dataset("small");
    hp.t = 40;
    hp.j0 = 6;
    hp.t0 = 5;
    hp
}

#[test]
fn preview_bitwise_matches_delete_gd() {
    let mut eng = engine();
    let spec = eng.spec("small").unwrap().clone();
    let (ds, test) = synth::train_test_for_spec(&spec, 3, Some(640), Some(64));
    let hp = small_hp();
    let session = SessionBuilder::new("small")
        .hyper_params(hp.clone())
        .datasets(ds.clone(), test)
        .build_in(&mut eng)
        .unwrap();
    let exes = eng.model("small").unwrap();
    let removed = sample_removal(&mut Rng::new(5), ds.n, 10);

    let old = batch::delete_gd(&exes, &eng.rt, &ds, session.trajectory(), &hp, &removed).unwrap();
    let pv = session.preview(&Edit::Delete(removed)).unwrap();
    assert_eq!(pv.mode, PassMode::Gd);
    assert_eq!(pv.out.w, old.w, "session preview drifted from delete_gd");
    assert_eq!(pv.out.n_exact, old.n_exact);
    assert_eq!(pv.out.n_approx, old.n_approx);
}

#[test]
fn preview_add_bitwise_matches_add_gd() {
    let mut eng = engine();
    let spec = eng.spec("small").unwrap().clone();
    let (ds, test) = synth::train_test_for_spec(&spec, 11, Some(640), Some(64));
    let hp = small_hp();
    let session = SessionBuilder::new("small")
        .hyper_params(hp.clone())
        .datasets(ds.clone(), test)
        .build_in(&mut eng)
        .unwrap();
    let exes = eng.model("small").unwrap();
    let added = synth::addition_rows(&spec, 23, 8);

    let old = batch::add_gd(&exes, &eng.rt, &ds, session.trajectory(), &hp, &added).unwrap();
    let pv = session.preview(&Edit::Add(added)).unwrap();
    assert_eq!(pv.out.w, old.w, "session add preview drifted from add_gd");
}

#[test]
fn pure_delete_commit_bitwise_matches_old_apply_group() {
    // single-kind groups keep the seed schedule exactly, so the pin
    // stays BITWISE (mixed groups now fuse their signed chain — see
    // mixed_group_commit_fuses_signed_chain)
    let mut eng = engine();
    let spec = eng.spec("small").unwrap().clone();
    let (ds, test) = synth::train_test_for_spec(&spec, 7, Some(640), Some(64));
    let hp = small_hp();
    let mut session = SessionBuilder::new("small")
        .hyper_params(hp.clone())
        .datasets(ds.clone(), test)
        .build_in(&mut eng)
        .unwrap();
    let exes = eng.model("small").unwrap();

    let del_rows = vec![4usize, 17, 130]; // sorted: matches commit's staging order
    let no_adds = synth::addition_rows(&spec, 9, 0);
    let (w_ref, traj_ref) = deltagrad::testing::baseline::online_group_seed_shape(
        &exes,
        &eng.rt,
        &ds,
        session.trajectory(),
        &hp,
        &del_rows,
        &no_adds,
    )
    .unwrap();

    let c = session
        .commit(Edit::Delete(IndexSet::from_vec(del_rows.clone())))
        .unwrap();
    assert_eq!(c.version, 1);
    assert_eq!(c.out.w, w_ref, "commit drifted from the old apply_group loop");
    assert_eq!(session.w(), &w_ref[..]);
    for t in 0..hp.t {
        assert_eq!(
            session.trajectory().ws[t], traj_ref.ws[t],
            "rewritten w cache drifted at iteration {t}"
        );
        assert_eq!(
            session.trajectory().gs[t], traj_ref.gs[t],
            "rewritten g cache drifted at iteration {t}"
        );
    }
    assert_eq!(session.n_current(), ds.n - 3);

    // the double-buffered rewrite must stay bitwise across commits: a
    // fork (fresh allocations, identical resident floats) and the
    // original (recycled previous-generation buffers) must agree
    // exactly on the next commit
    let mut fork = session.fork().unwrap();
    let adds2 = synth::addition_rows(&spec, 21, 2);
    let c2 = session.commit(Edit::Add(adds2.clone())).unwrap();
    let c2f = fork.commit(Edit::Add(adds2)).unwrap();
    assert_eq!(
        c2.out.w, c2f.out.w,
        "recycled trajectory buffers changed the floats"
    );
    for t in 0..hp.t {
        assert_eq!(
            session.trajectory().gs[t], fork.trajectory().gs[t],
            "recycled g cache drifted at iteration {t}"
        );
    }
}

#[test]
fn mixed_group_commit_fuses_signed_chain() {
    // a mixed delete+add group now runs its signed group gradient as
    // ONE ±1-masked chain: one download per iteration instead of two.
    // The fusion reorders the f32 reduction (device chain vs host
    // combine), so the pin against the seed-shape two-chain loop is a
    // tight tolerance, not bitwise.
    let mut eng = engine();
    let spec = eng.spec("small").unwrap().clone();
    let (ds, test) = synth::train_test_for_spec(&spec, 7, Some(640), Some(64));
    let hp = small_hp();
    let mut session = SessionBuilder::new("small")
        .hyper_params(hp.clone())
        .datasets(ds.clone(), test)
        .build_in(&mut eng)
        .unwrap();
    let exes = eng.model("small").unwrap();

    let adds = synth::addition_rows(&spec, 9, 1);
    let adds_n = adds.n;
    let del_rows = vec![4usize, 17, 130];
    let (w_ref, _) = deltagrad::testing::baseline::online_group_seed_shape(
        &exes,
        &eng.rt,
        &ds,
        session.trajectory(),
        &hp,
        &del_rows,
        &adds,
    )
    .unwrap();

    let edit = Edit::group(vec![
        Edit::Delete(IndexSet::from_vec(del_rows.clone())),
        Edit::Add(adds),
    ]);
    let c = session.commit(edit).unwrap();
    assert_eq!(c.version, 1);
    assert_eq!(session.n_current(), ds.n - 3 + 1);
    let denom = w_ref.iter().map(|x| x.abs()).fold(1e-12f32, f32::max) as f64;
    let d = deltagrad::util::vecmath::dist2(&c.out.w, &w_ref);
    assert!(
        d / denom < 1e-5,
        "fused mixed commit drifted from the two-chain loop: {:.3e}",
        d / denom
    );

    // the fused budget: ONE signed-group download per iteration plus
    // the full-data gradient at exact iterations — the two-chain loop
    // paid 2T + exact
    assert_eq!(
        c.out.transfers.downloads,
        (hp.t + c.out.n_exact) as u64,
        "mixed commit must download one fused signed gradient per iteration"
    );
    // uploads: del rows staged −1-masked (no cache) + add rows + T
    // params + the touched removal-mask chunk
    let del_groups = del_rows.len().div_ceil(spec.chunk_small);
    let add_groups = adds_n.div_ceil(spec.chunk_small);
    assert_eq!(
        c.out.transfers.uploads,
        (3 * del_groups + 3 * add_groups + hp.t + 1) as u64,
        "mixed commit upload schedule changed"
    );
}

#[test]
fn tail_compaction_caps_launches_and_preserves_floats() {
    // long-lived serving sessions: add commits accumulate StagedRows
    // segments until the watermark, then commit folds them into
    // full-size Staged chunks — ≤ ⌈tail/chunk⌉ launches per full
    // gradient — without changing results beyond f32 reduction order
    let mut eng = engine();
    let spec = eng.spec("small").unwrap().clone();
    let (ds, test) = synth::train_test_for_spec(&spec, 27, Some(640), Some(64));
    let hp = small_hp();
    let mut session = SessionBuilder::new("small")
        .hyper_params(hp.clone())
        .datasets(ds.clone(), test)
        .tail_compact_watermark(4)
        .build_in(&mut eng)
        .unwrap();

    // 3 add commits of one row each: 3 segment groups, below watermark
    for i in 0..3 {
        session
            .commit(Edit::Add(synth::addition_rows(&spec, 100 + i, 1)))
            .unwrap();
        assert_eq!(session.tail_launches(), (i + 1) as usize);
    }
    // the 4th crosses the watermark: segments fold into ⌈4/chunk⌉ = 1
    // full-size chunk
    session
        .commit(Edit::Add(synth::addition_rows(&spec, 104, 1)))
        .unwrap();
    assert_eq!(
        session.tail_launches(),
        4usize.div_ceil(spec.chunk),
        "compaction must cap tail launches at ⌈tail/chunk⌉"
    );

    // parity: a fork re-stages the same tail from scratch (below the
    // watermark it stays one SEGMENT, giving the segmented-vs-compacted
    // contrast); previews of the same edit must agree to
    // f32-reduction-order tolerance
    let fork = session.fork().unwrap();
    let edit = Edit::delete_row(7);
    let a = session.preview(&edit).unwrap();
    let b = fork.preview(&edit).unwrap();
    assert_eq!(a.out.n_exact, b.out.n_exact);
    let denom = b.out.w.iter().map(|x| x.abs()).fold(1e-12f32, f32::max) as f64;
    let d = deltagrad::util::vecmath::dist2(&a.out.w, &b.out.w);
    assert!(
        d / denom < 1e-5,
        "compacted-tail preview drifted from segmented staging: {:.3e}",
        d / denom
    );

    // the compacted execution budget, from the preview's own counters:
    // T delta-row launches + per exact iteration (base chunks + the
    // compacted tail's ⌈4/chunk⌉ = 1 launch) — not one per segment
    let base_chunks = ds.n.div_ceil(spec.chunk);
    assert_eq!(
        a.out.transfers.execs,
        (hp.t + a.out.n_exact * (base_chunks + session.tail_launches())) as u64,
        "compacted-tail exec schedule changed"
    );
}

#[test]
fn delete_committed_added_rows_segmented_and_compacted() {
    // the PERFORMANCE.md gap, closed: a committed ADDED row (index
    // base.n + j) can be deleted. Below the compaction watermark the
    // owning segment's multiplicity mask is rewritten in place; past it
    // the compacted tail chunk's mask flips. Both paths must agree with
    // a freshly-staged fork to reduction-order tolerance and keep the
    // masked row counts exact.
    let mut eng = engine();
    let spec = eng.spec("small").unwrap().clone();
    let (ds, test) = synth::train_test_for_spec(&spec, 31, Some(640), Some(64));
    let hp = small_hp();
    let mut session = SessionBuilder::new("small")
        .hyper_params(hp.clone())
        .datasets(ds.clone(), test)
        .tail_compact_watermark(4)
        .build_in(&mut eng)
        .unwrap();
    let n0 = ds.n;

    // one add commit of 3 rows -> one segment, indices n0..n0+3
    session
        .commit(Edit::Add(synth::addition_rows(&spec, 41, 3)))
        .unwrap();
    assert_eq!(session.n_current(), n0 + 3);

    // SEGMENTED path: delete the middle added row
    let c = session.commit(Edit::delete_row(n0 + 1)).unwrap();
    assert_eq!(c.version, 2);
    assert_eq!(session.n_current(), n0 + 2);
    // the masked row count of a full pass must be exact (empty preview
    // replays the trajectory; its exact iterations evaluate base + tail)
    let pv = session.preview(&Edit::Delete(IndexSet::empty())).unwrap();
    assert_eq!(pv.out.last_stats.cnt as usize, n0 + 2, "tail mask row count drifted");
    // parity vs a fork (fresh staging of the same live rows)
    let fork = session.fork().unwrap();
    let probe = Edit::delete_row(7);
    let a = session.preview(&probe).unwrap();
    let b = fork.preview(&probe).unwrap();
    assert_eq!(a.out.n_exact, b.out.n_exact);
    let denom = b.out.w.iter().map(|x| x.abs()).fold(1e-12f32, f32::max) as f64;
    let d = deltagrad::util::vecmath::dist2(&a.out.w, &b.out.w);
    assert!(d / denom < 1e-5, "segment-rewrite drifted from fresh staging: {:.3e}", d / denom);

    // double-delete of the added row and out-of-range both reject
    assert!(session.commit(Edit::delete_row(n0 + 1)).is_err());
    assert!(session.commit(Edit::delete_row(n0 + 999)).is_err());
    assert!(session.preview(&Edit::delete_row(n0 + 1)).is_err());

    // cross the watermark: 4 more one-row adds compact the tail (the
    // deleted row must stay masked in the compacted staging)
    for i in 0..4u64 {
        session
            .commit(Edit::Add(synth::addition_rows(&spec, 50 + i, 1)))
            .unwrap();
    }
    assert_eq!(session.n_current(), n0 + 6);
    // the 3rd one-row add crossed the watermark (4 segment groups), so
    // the first 6 added rows compacted into ⌈6/chunk⌉ full-size chunks
    // and the 4th add opened a fresh one-group segment
    assert_eq!(
        session.tail_launches(),
        6usize.div_ceil(spec.chunk) + 1,
        "compaction must fold the segments"
    );
    let pv = session.preview(&Edit::Delete(IndexSet::empty())).unwrap();
    assert_eq!(pv.out.last_stats.cnt as usize, n0 + 6, "compacted tail lost the deletion");

    // COMPACTED path: delete the first added row (lives in the
    // compacted chunk now) — mask flip, no re-staging of the tail
    session.commit(Edit::delete_row(n0)).unwrap();
    assert_eq!(session.n_current(), n0 + 5);
    let pv = session.preview(&Edit::Delete(IndexSet::empty())).unwrap();
    assert_eq!(pv.out.last_stats.cnt as usize, n0 + 5);
    let fork = session.fork().unwrap();
    let a = session.preview(&probe).unwrap();
    let b = fork.preview(&probe).unwrap();
    let denom = b.out.w.iter().map(|x| x.abs()).fold(1e-12f32, f32::max) as f64;
    let d = deltagrad::util::vecmath::dist2(&a.out.w, &b.out.w);
    assert!(d / denom < 1e-5, "compacted mask flip drifted: {:.3e}", d / denom);

    // a BaseL baseline built from the session agrees on the dataset:
    // current_dataset excludes both deleted added rows
    assert_eq!(session.current_dataset().n, n0 + 5);

    // mixed group touching base AND added rows commits in one pass
    let c = session
        .commit(Edit::group(vec![
            Edit::delete_row(3),
            Edit::delete_row(n0 + 2),
            Edit::Add(synth::addition_rows(&spec, 77, 1)),
        ]))
        .unwrap();
    assert!(c.out.n_exact > 0);
    assert_eq!(session.n_current(), n0 + 4);
}

#[test]
fn interleaved_previews_are_independent_and_commit_free() {
    let mut eng = engine();
    let spec = eng.spec("small").unwrap().clone();
    let (ds, test) = synth::train_test_for_spec(&spec, 13, Some(640), Some(64));
    let session = SessionBuilder::new("small")
        .hyper_params(small_hp())
        .datasets(ds.clone(), test)
        .build_in(&mut eng)
        .unwrap();
    let w0 = session.w().to_vec();
    let e1 = Edit::Delete(sample_removal(&mut Rng::new(1), ds.n, 7));
    let e2 = Edit::Delete(sample_removal(&mut Rng::new(2), ds.n, 13));

    // interleave: e1, e2, e1 again, e2 again — repeats must be bitwise
    // stable (no hidden state leaks between speculative passes)
    let p1a = session.preview(&e1).unwrap();
    let p2a = session.preview(&e2).unwrap();
    let p1b = session.preview(&e1).unwrap();
    let p2b = session.preview(&e2).unwrap();
    assert_eq!(p1a.out.w, p1b.out.w, "repeated preview of e1 drifted");
    assert_eq!(p2a.out.w, p2b.out.w, "repeated preview of e2 drifted");
    assert_ne!(p1a.out.w, p2a.out.w, "distinct edits must differ");

    // and none of it committed anything
    assert_eq!(session.version(), 0);
    assert_eq!(session.w(), &w0[..]);
    assert_eq!(session.n_current(), ds.n);
    assert!(session.removed().is_empty());
    let stats = session.stats();
    assert_eq!(stats.previews, 4);
    assert_eq!(stats.commits, 0);
    assert_eq!(stats.commit_transfers.uploads, 0);
}

#[test]
fn previews_after_commit_run_against_committed_state() {
    // a preview between commits must see the committed base (masked
    // rows + rewritten trajectory), and committing after previews must
    // be unaffected by them
    let mut eng = engine();
    let spec = eng.spec("small").unwrap().clone();
    let (ds, test) = synth::train_test_for_spec(&spec, 19, Some(640), Some(64));
    let hp = small_hp();
    let mut s_plain = SessionBuilder::new("small")
        .hyper_params(hp.clone())
        .datasets(ds.clone(), test.clone())
        .build_in(&mut eng)
        .unwrap();
    let mut s_previewed = s_plain.fork().unwrap();

    // session B runs speculative work first; both then commit the same edit
    let probe = Edit::Delete(sample_removal(&mut Rng::new(3), ds.n, 5));
    s_previewed.preview(&probe).unwrap();
    let edit = Edit::Delete(IndexSet::from_vec(vec![2, 40]));
    let c_plain = s_plain.commit(edit.clone()).unwrap();
    let c_previewed = s_previewed.commit(edit).unwrap();
    assert_eq!(
        c_plain.out.w, c_previewed.out.w,
        "speculative previews leaked into the committed state"
    );

    // previewing a deleted row must now be rejected
    assert!(s_plain.preview(&Edit::delete_row(2)).is_err());
    // and a fresh preview runs against n_current = n - 2
    let pv = s_plain.preview(&Edit::delete_row(3)).unwrap();
    assert!(pv.out.n_exact > 0);
}

#[test]
fn auto_mode_selection_follows_batch_schedule() {
    let mut eng = engine();
    let spec = eng.spec("small").unwrap().clone();
    let (ds, test) = synth::train_test_for_spec(&spec, 21, Some(640), Some(64));

    // GD trajectory -> Gd mode
    let gd = SessionBuilder::new("small")
        .hyper_params(small_hp())
        .datasets(ds.clone(), test.clone())
        .build_in(&mut eng)
        .unwrap();
    assert_eq!(gd.mode(), PassMode::Gd);
    assert!(gd.trajectory().batches.iter().all(|b| b.is_empty()));

    // SGD trajectory -> Sgd mode, bitwise-equal to the old delete_sgd
    let mut hp = small_hp();
    hp.batch = 512;
    let sgd = SessionBuilder::new("small")
        .hyper_params(hp.clone())
        .datasets(ds.clone(), test)
        .build_in(&mut eng)
        .unwrap();
    assert_eq!(sgd.mode(), PassMode::Sgd);
    assert!(sgd.trajectory().batches.iter().all(|b| !b.is_empty()));
    let exes = eng.model("small").unwrap();
    let removed = sample_removal(&mut Rng::new(21), ds.n, 10);
    let old = batch::delete_sgd(&exes, &eng.rt, &ds, sgd.trajectory(), &hp, &removed).unwrap();
    let pv = sgd.preview(&Edit::Delete(removed)).unwrap();
    assert_eq!(pv.mode, PassMode::Sgd);
    assert_eq!(pv.out.w, old.w, "SGD preview drifted from delete_sgd");

    // SGD sessions are preview-only
    let mut sgd = sgd;
    assert!(sgd.commit(Edit::delete_row(0)).is_err());
}

#[test]
fn preview_upload_budget_pays_no_base_restaging() {
    // the session's base is resident: a preview ships only the delta
    // rows (3 buffers per chunk_small group) + one parameter upload per
    // iteration — the tests/staging.rs budget with the dataset term gone
    let mut eng = engine();
    let spec = eng.spec("small").unwrap().clone();
    let (ds, test) = synth::train_test_for_spec(&spec, 9, Some(640), Some(64));
    let hp = small_hp();
    let session = SessionBuilder::new("small")
        .hyper_params(hp.clone())
        .datasets(ds.clone(), test)
        .build_in(&mut eng)
        .unwrap();
    let removed = sample_removal(&mut Rng::new(2), ds.n, 10);
    let pv = session.preview(&Edit::Delete(removed.clone())).unwrap();
    let delta_groups = removed.len().div_ceil(spec.chunk_small);
    assert_eq!(
        pv.out.transfers.uploads,
        (3 * delta_groups + hp.t) as u64,
        "preview upload schedule changed"
    );
    // fused-reduction download budget: the delta-row gradient downloads
    // once per iteration, the full-data gradient once per exact
    // iteration — nothing per chunk
    assert_eq!(
        pv.out.transfers.downloads,
        (hp.t + pv.out.n_exact) as u64,
        "preview download schedule changed"
    );
    let stats = session.stats();
    assert_eq!(stats.preview_transfers.uploads, pv.out.transfers.uploads);

    // repeated preview of the SAME edit: the cross-pass row cache serves
    // the delta rows, so the staging term disappears entirely
    let pv2 = session.preview(&Edit::Delete(removed.clone())).unwrap();
    assert_eq!(
        pv2.out.transfers.uploads,
        hp.t as u64,
        "repeated preview must re-stage nothing (row cache)"
    );
    assert_eq!(pv2.out.w, pv.out.w, "cache hit changed the floats");
    let stats = session.stats();
    assert_eq!(stats.row_cache_hits, 1);
    assert_eq!(stats.row_cache_misses, 1);
}

#[test]
fn preview_then_commit_stages_delta_rows_once() {
    // the preview stages the edit's delta rows (keyed by the sorted
    // set); the commit of the same edit — even written in a different
    // group order — must find them and re-stage nothing
    let mut eng = engine();
    let spec = eng.spec("small").unwrap().clone();
    let (ds, test) = synth::train_test_for_spec(&spec, 29, Some(640), Some(64));
    let hp = small_hp();
    let mut session = SessionBuilder::new("small")
        .hyper_params(hp.clone())
        .datasets(ds, test)
        .build_in(&mut eng)
        .unwrap();
    let edit = Edit::group(vec![Edit::delete_row(9), Edit::delete_row(2)]);
    session.preview(&edit).unwrap(); // miss: stages sorted [2, 9]
    let c = session.commit(edit).unwrap(); // hit: reuses the staging
    let stats = session.stats();
    assert_eq!(
        (stats.row_cache_hits, stats.row_cache_misses),
        (1, 1),
        "commit must reuse the previewed staging"
    );
    // commit budget with the staging term gone: T params + the one
    // touched removal-mask chunk (rows 2 and 9 share chunk 0)
    assert!(9 < spec.chunk);
    assert_eq!(
        c.out.transfers.uploads,
        (hp.t + 1) as u64,
        "previewed-then-committed edit must not re-stage its delta rows"
    );
}

#[test]
fn row_cache_serves_interleaved_folds_and_survives_commits() {
    // conformal/jackknife shape: alternating previews over two fixed
    // folds must stage each fold exactly once; after a commit the cache
    // stays valid (base rows are immutable, deletions are masks)
    let mut eng = engine();
    let spec = eng.spec("small").unwrap().clone();
    let (ds, test) = synth::train_test_for_spec(&spec, 23, Some(640), Some(64));
    let mut session = SessionBuilder::new("small")
        .hyper_params(small_hp())
        .datasets(ds.clone(), test)
        .build_in(&mut eng)
        .unwrap();
    let set_a = sample_removal(&mut Rng::new(1), ds.n, 8);
    let set_b = sample_removal(&mut Rng::new(2), ds.n, 8);
    // a victim row in neither fold, so fold previews stay valid after
    // the commit deletes it
    let victim = (0..ds.n)
        .find(|&i| !set_a.contains(i) && !set_b.contains(i))
        .unwrap();
    let fold_a = Edit::Delete(set_a);
    let fold_b = Edit::Delete(set_b);

    session.preview(&fold_a).unwrap(); // miss
    session.preview(&fold_b).unwrap(); // miss
    let a2 = session.preview(&fold_a).unwrap(); // hit
    let b2 = session.preview(&fold_b).unwrap(); // hit
    let stats = session.stats();
    assert_eq!((stats.row_cache_hits, stats.row_cache_misses), (2, 2));
    assert_eq!(a2.out.transfers.uploads, small_hp().t as u64);
    assert_eq!(b2.out.transfers.uploads, small_hp().t as u64);

    // a commit of an unrelated row leaves cached fold stagings valid
    session.commit(Edit::delete_row(victim)).unwrap();
    let a3 = session.preview(&fold_a).unwrap();
    assert_eq!(
        a3.out.transfers.uploads,
        small_hp().t as u64,
        "fold staging must survive an unrelated commit"
    );
    let stats = session.stats();
    assert_eq!(stats.row_cache_hits, 3);
    // the commit's single-row delta was a lookup miss (it staged
    // directly — committed rows can never be staged again, so commit
    // misses do not populate the cache)
    assert_eq!(stats.row_cache_misses, 3);
}
