//! Fault-tolerance integration: deterministic fault injection, reader
//! supervision & respawn, and crash-recoverable commits via the edit-log
//! WAL. Every recovery claim is pinned BITWISE against an uninjected /
//! offline twin — surviving a fault is not enough, the recovered state
//! must be indistinguishable from one that never failed.
//! Requires `make artifacts`.

use std::path::PathBuf;
use std::time::Duration;

use deltagrad::config::HyperParams;
use deltagrad::coordinator::{
    BatchPolicy, FaultConfig, FaultSite, Rejected, ServiceConfig, ServiceHandle, Supervision,
};
use deltagrad::session::{artifact, Edit, Query, QueryResult, Session, SessionBuilder};

/// Per-test scratch store (checkpoints + WAL), wiped on drop so reruns
/// never see a previous run's files.
struct Store(PathBuf);

impl Store {
    fn new(tag: &str) -> Store {
        let p = std::env::temp_dir()
            .join(format!("deltagrad-test-recovery-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&p);
        Store(p)
    }
    fn path(&self) -> &std::path::Path {
        &self.0
    }
}

impl Drop for Store {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}

fn small_hp() -> HyperParams {
    let mut hp = HyperParams::for_dataset("small");
    hp.t = 40;
    hp.j0 = 6;
    hp.t0 = 5;
    hp
}

/// The service recipe every test uses (same as tests/service.rs), one
/// edit per pass so versions are deterministic.
fn base_cfg() -> ServiceConfig {
    ServiceConfig {
        model: "small".into(),
        seed: 77,
        n_train: Some(512),
        n_test: Some(256),
        hp: small_hp(),
        policy: BatchPolicy {
            max_group: 1,
            max_wait: Duration::from_millis(1),
            ..BatchPolicy::default()
        },
        readers: 0,
        query_cache: 0,
        query_cache_bytes: 0,
        shards: 1,
        checkpoint_every: 0,
        checkpoint_dir: None,
        checkpoint_keep: 4,
        wal: false,
        restore_latest: false,
        store_fresh: false,
        supervision: Supervision::default(),
        faults: None,
        certify: None,
    }
}

/// Offline twin: same recipe, `n` single-row deletions, no service, no
/// faults — the bitwise reference every recovery path must match.
fn offline_twin(n: usize) -> Session {
    let mut s = SessionBuilder::new("small")
        .seed(77)
        .n_train(Some(512))
        .n_test(Some(256))
        .hyper_params(small_hp())
        .build()
        .unwrap();
    for i in 0..n {
        s.commit(Edit::delete_row(i)).unwrap();
    }
    s
}

fn w_bits(w: &[f32]) -> Vec<u32> {
    w.iter().map(|x| x.to_bits()).collect()
}

fn loss_bits(r: &QueryResult) -> [u64; 4] {
    match r {
        QueryResult::Loss { test_loss, test_accuracy, train_loss, train_accuracy } => [
            test_loss.to_bits(),
            test_accuracy.to_bits(),
            train_loss.to_bits(),
            train_accuracy.to_bits(),
        ],
        other => panic!("wrong reply kind: {other:?}"),
    }
}

#[test]
fn reader_respawns_after_injected_replay_faults_and_stays_bitwise() {
    // every delta replay is killed by an injected fault, so the single
    // replica must respawn (spawn artifact + WAL catch-up) to serve at
    // all — and what it serves must still be bitwise the offline model
    let store = Store::new("respawn");
    let svc = ServiceHandle::spawn(ServiceConfig {
        readers: 1,
        wal: true,
        checkpoint_dir: Some(store.path().to_path_buf()),
        faults: Some(FaultConfig {
            seed: 1,
            rate: 1.0,
            sites: Some(vec![FaultSite::ReaderReplay]),
            budget: None,
        }),
        ..base_cfg()
    })
    .unwrap();
    for i in 0..3 {
        let rep = svc.update(Edit::delete_row(i)).unwrap();
        assert_eq!(rep.version, (i + 1) as u64);
    }
    // quiescence: the replica has recovered to the writer's version (a
    // respawn can swallow several versions at once via the WAL, so the
    // respawn count is 1..=3, not exactly 3)
    let deadline = std::time::Instant::now() + Duration::from_secs(60);
    loop {
        let m = svc.metrics().unwrap();
        if m.replica_min_version == 3 {
            assert!(
                (1..=3).contains(&m.respawns),
                "expected 1..=3 respawns, got {}",
                m.respawns
            );
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "replica never recovered: min_version {}, respawns {}",
            m.replica_min_version,
            m.respawns
        );
        std::thread::sleep(Duration::from_millis(10));
    }
    let rep = svc.query(Query::Loss).unwrap();
    assert_eq!(rep.version, 3, "the recovered replica must serve at the writer's version");
    let m = svc.metrics().unwrap();
    assert!(m.wal_records >= 3, "every commit must have been journaled");
    svc.shutdown().unwrap();

    let twin = offline_twin(3);
    assert_eq!(
        loss_bits(&rep.result),
        loss_bits(&twin.query(&Query::Loss).unwrap().result),
        "respawned replica diverged from the offline twin"
    );
}

#[test]
fn corrupt_checkpoint_falls_back_to_previous_and_wal_covers_the_gap() {
    // offline: two checkpoints + a two-record journal; corrupt the
    // newest checkpoint on disk. Recovery must detect the bad hash,
    // fall back to the older checkpoint, and close the gap via the WAL
    let store = Store::new("corrupt");
    let mut live = offline_twin(0);
    let wal_p = artifact::wal_path(store.path(), "small");
    let mut wal = artifact::WalWriter::create(&wal_p).unwrap();
    for i in 0..2 {
        let c = live.commit(Edit::delete_row(i)).unwrap();
        wal.append(c.version, &Edit::delete_row(i)).unwrap();
        artifact::save_to_store(&live, store.path()).unwrap();
    }
    let cps = artifact::store_checkpoints(store.path(), "small").unwrap();
    assert_eq!(cps.iter().map(|(v, _)| *v).collect::<Vec<_>>(), vec![2, 1]);
    // flip one payload byte of the v2 checkpoint: its content hash no
    // longer verifies, so restore must refuse it
    let v2_path = &cps[0].1;
    let mut bytes = std::fs::read(v2_path).unwrap();
    let last = bytes.len() - 9;
    bytes[last] ^= 0x40;
    std::fs::write(v2_path, &bytes).unwrap();

    let recovered = artifact::restore_latest(store.path(), "small").unwrap();
    assert_eq!(recovered.version(), 2, "v1 checkpoint + WAL replay must land on v2");
    assert_eq!(
        w_bits(&recovered.snapshot().unwrap().w),
        w_bits(&live.snapshot().unwrap().w),
        "recovered model diverged from the live session"
    );

    // without the journal, the same corruption is only recoverable to
    // the older checkpoint — still typed, still no panic
    std::fs::remove_file(&wal_p).unwrap();
    let older = artifact::restore_latest(store.path(), "small").unwrap();
    assert_eq!(older.version(), 1);
}

#[test]
fn injected_pass_fault_rejects_typed_and_the_session_stays_clean() {
    // budget 1: exactly the first pass dies at device upload. The group
    // gets a typed Rejected::Failed, the session is untouched, and the
    // retried stream commits to bitwise the uninjected model
    let svc = ServiceHandle::spawn(ServiceConfig {
        faults: Some(FaultConfig {
            seed: 5,
            rate: 1.0,
            sites: Some(vec![FaultSite::DeviceUpload]),
            budget: Some(1),
        }),
        ..base_cfg()
    })
    .unwrap();
    match svc.update(Edit::delete_row(0)) {
        Err(Rejected::Failed(e)) => {
            assert!(e.contains("injected"), "unexpected failure message: {e}")
        }
        other => panic!("expected the injected fault to reject the first pass, got {other:?}"),
    }
    // the budget is spent: the retry and everything after commit clean
    assert_eq!(svc.update(Edit::delete_row(0)).unwrap().version, 1);
    assert_eq!(svc.update(Edit::delete_row(1)).unwrap().version, 2);
    let snap = svc.snapshot().unwrap();
    let m = svc.metrics().unwrap();
    assert_eq!(m.requests, 2, "only the served groups count");
    assert_eq!(m.respawns, 0);
    svc.shutdown().unwrap();

    let twin = offline_twin(2);
    assert_eq!(
        w_bits(&snap.w),
        w_bits(&twin.snapshot().unwrap().w),
        "a rejected pass must leave no trace in the committed state"
    );
}

#[test]
fn wal_recovery_after_shutdown_is_bitwise_via_divergence_audit() {
    // 5 commits with checkpoints every 2: the store holds v2/v4, the
    // journal holds the suffix the retention truncation left. A cold
    // restore must reach v5 and be bitwise-indistinguishable from an
    // offline twin — audited field by field by artifact::divergence
    let store = Store::new("wal");
    let svc = ServiceHandle::spawn(ServiceConfig {
        wal: true,
        checkpoint_every: 2,
        checkpoint_dir: Some(store.path().to_path_buf()),
        ..base_cfg()
    })
    .unwrap();
    for i in 0..5 {
        assert_eq!(svc.update(Edit::delete_row(i)).unwrap().version, (i + 1) as u64);
    }
    let m = svc.metrics().unwrap();
    assert_eq!(m.wal_records, 5, "every commit journals exactly one record");
    assert_eq!(m.checkpoints, 2);
    svc.shutdown().unwrap();

    // the journal was truncated to the oldest retained checkpoint (v2),
    // so only the suffix survives — recovery still has v4 + v5 covered
    let recs = artifact::read_wal(&artifact::wal_path(store.path(), "small")).unwrap();
    assert_eq!(recs.iter().map(|r| r.version).collect::<Vec<_>>(), vec![3, 4, 5]);

    let recovered = artifact::restore_latest(store.path(), "small").unwrap();
    assert_eq!(recovered.version(), 5, "checkpoint v4 + WAL v5 must reach the final state");

    let twin = offline_twin(5);
    let twin_path = std::env::temp_dir()
        .join(format!("deltagrad-test-recovery-twin-{}.dgar", std::process::id()));
    let _ = std::fs::remove_file(&twin_path);
    twin.save_artifact(&twin_path).unwrap();
    let twin_art = artifact::Artifact::load(&twin_path).unwrap();
    let _ = std::fs::remove_file(&twin_path);
    let diffs = artifact::divergence(&twin_art, &recovered);
    assert!(
        diffs.is_empty(),
        "WAL recovery diverged from the offline twin: {diffs:?}"
    );
}

#[test]
fn checkpoint_retention_keeps_only_the_newest_k() {
    let store = Store::new("retention");
    let svc = ServiceHandle::spawn(ServiceConfig {
        checkpoint_every: 1,
        checkpoint_keep: 2,
        checkpoint_dir: Some(store.path().to_path_buf()),
        ..base_cfg()
    })
    .unwrap();
    for i in 0..4 {
        svc.update(Edit::delete_row(i)).unwrap();
    }
    let m = svc.metrics().unwrap();
    assert_eq!(m.checkpoints, 4, "every commit checkpointed");
    svc.shutdown().unwrap();
    let cps = artifact::store_checkpoints(store.path(), "small").unwrap();
    assert_eq!(
        cps.iter().map(|(v, _)| *v).collect::<Vec<_>>(),
        vec![4, 3],
        "retention must prune to the newest 2 checkpoints"
    );
}
