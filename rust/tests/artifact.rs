//! Durable session artifacts end to end: bitwise warm restore, edit-log
//! replay, and the typed failure surface of the wire format, against a
//! real device session. Requires `make artifacts`.

use std::path::PathBuf;

use deltagrad::config::HyperParams;
use deltagrad::session::artifact::{self, Artifact, ArtifactError};
use deltagrad::session::{Edit, Query, QueryResult, Session, SessionBuilder};

fn quick_session(t: usize) -> Session {
    let mut hp = HyperParams::for_dataset("small");
    hp.t = t;
    hp.j0 = 6;
    hp.t0 = 5;
    SessionBuilder::new("small")
        .seed(77)
        .n_train(Some(512))
        .n_test(Some(256))
        .hyper_params(hp)
        .build()
        .unwrap()
}

fn tmp_path(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("deltagrad-test-{tag}-{}.dgar", std::process::id()))
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// A fabricated addition row for the small config: zeros + bias column.
fn add_row_for(s: &Session) -> Edit {
    let da = s.train_dataset().da;
    let k = s.train_dataset().k;
    let mut x = vec![0.0f32; da];
    x[da - 1] = 1.0;
    Edit::add_row(x, 1, k)
}

fn loss_bits(r: &QueryResult) -> [u64; 4] {
    match r {
        QueryResult::Loss { test_loss, test_accuracy, train_loss, train_accuracy } => [
            test_loss.to_bits(),
            test_accuracy.to_bits(),
            train_loss.to_bits(),
            train_accuracy.to_bits(),
        ],
        other => panic!("wrong reply kind: {other:?}"),
    }
}

#[test]
fn restore_is_bitwise_with_zero_training() {
    let mut live = quick_session(40);
    // two committed edit groups so the artifact carries a removal mask,
    // a staged tail, and a non-trivial edit log
    live.commit(Edit::delete_row(3)).unwrap();
    let add = add_row_for(&live);
    live.commit(add).unwrap();

    let path = tmp_path("restore");
    let _ = std::fs::remove_file(&path);
    let report = live.save_artifact(&path).unwrap();
    assert!(report.fresh);
    assert_eq!(report.content_hash, Artifact::load(&path).unwrap().content_hash);

    let restored = SessionBuilder::restore_from(&path).unwrap();
    // zero training iterations: the restore's runtime has only re-staged
    // host rows — uploads, never a gradient download
    let tr = restored.runtime().counters.snapshot();
    assert!(tr.uploads > 0, "restore must re-stage the resident buffers");
    assert_eq!(tr.downloads, 0, "restore must not run a single training iteration");

    assert_eq!(restored.version(), live.version());
    assert_eq!(bits(restored.w()), bits(live.w()), "parameters must restore bitwise");
    let (lt, rt2) = (live.trajectory(), restored.trajectory());
    assert_eq!(lt.ws.len(), rt2.ws.len());
    for (a, b) in lt.ws.iter().zip(&rt2.ws) {
        assert_eq!(bits(a), bits(b), "trajectory ws must restore bitwise");
    }
    for (a, b) in lt.gs.iter().zip(&rt2.gs) {
        assert_eq!(bits(a), bits(b), "trajectory gs must restore bitwise");
    }
    assert_eq!(lt.n_effective, rt2.n_effective);
    assert_eq!(restored.train_dataset().n, live.train_dataset().n);
    assert_eq!(restored.edit_log().len(), 2);

    // SessionStats continuity: the restored session keeps counting from
    // where the saved one stopped
    let (a, b) = (live.stats(), restored.stats());
    assert_eq!(a.commits, b.commits);
    assert_eq!(a.rows_deleted, b.rows_deleted);
    assert_eq!(a.rows_added, b.rows_added);
    assert_eq!(a.exact_iters, b.exact_iters);
    assert_eq!(a.approx_iters, b.approx_iters);
    assert_eq!(a.row_cache_hits, b.row_cache_hits);
    assert_eq!(a.row_cache_misses, b.row_cache_misses);

    // reads off the re-staged device state are bitwise the live ones
    let lr = live.query(&Query::Loss).unwrap();
    let rr = restored.query(&Query::Loss).unwrap();
    assert_eq!(loss_bits(&lr.result), loss_bits(&rr.result));

    std::fs::remove_file(&path).unwrap();
}

#[test]
fn restored_sessions_keep_committing_in_lockstep() {
    // the synthesized section (staged chunks, tail segments, masks) is
    // recreated faithfully enough that the NEXT commit lands bitwise on
    // the same model as the original session's
    let mut live = quick_session(40);
    live.commit(Edit::delete_row(0)).unwrap();
    let add = add_row_for(&live);
    live.commit(add).unwrap();

    let path = tmp_path("lockstep");
    let _ = std::fs::remove_file(&path);
    live.save_artifact(&path).unwrap();
    let mut restored = SessionBuilder::restore_from(&path).unwrap();

    let edit = Edit::group(vec![Edit::delete_row(7), Edit::delete_row(8)]);
    let cl = live.commit(edit.clone()).unwrap();
    let cr = restored.commit(edit).unwrap();
    assert_eq!(cl.version, cr.version);
    assert_eq!(cl.n_exact, cr.n_exact);
    assert_eq!(cl.n_approx, cr.n_approx);
    assert_eq!(bits(live.w()), bits(restored.w()), "post-restore commit diverged");
    assert_eq!(restored.edit_log().len(), 3, "the restored log keeps growing");

    std::fs::remove_file(&path).unwrap();
}

#[test]
fn replay_reproduces_the_live_session_bitwise() {
    let mut live = quick_session(40);
    // interleaved Delete / Add / Group — the full edit vocabulary
    live.commit(Edit::delete_row(0)).unwrap();
    let add = add_row_for(&live);
    live.commit(add).unwrap();
    live.commit(Edit::group(vec![Edit::delete_row(5), Edit::delete_row(6)]))
        .unwrap();

    let path = tmp_path("replay");
    let _ = std::fs::remove_file(&path);
    live.save_artifact(&path).unwrap();

    let art = Artifact::load(&path).unwrap();
    let replayed = artifact::replay(&path).unwrap();
    let diffs = artifact::divergence(&art, &replayed);
    assert!(diffs.is_empty(), "replay diverged from the stored session: {diffs:?}");
    assert_eq!(replayed.version(), 3);
    assert_eq!(bits(replayed.w()), bits(live.w()), "replay diverged from the live session");

    std::fs::remove_file(&path).unwrap();
}

#[test]
fn malformed_artifacts_fail_typed_and_saves_are_clobber_safe() {
    let mut live = quick_session(20);
    live.commit(Edit::delete_row(1)).unwrap();
    let path = tmp_path("wire");
    let _ = std::fs::remove_file(&path);
    assert!(live.save_artifact(&path).unwrap().fresh);
    // a same-content re-save is an idempotent no-op
    assert!(!live.save_artifact(&path).unwrap().fresh);

    let bytes = std::fs::read(&path).unwrap();
    let bad_path = tmp_path("wire-bad");
    let typed = |bytes: &[u8]| {
        std::fs::write(&bad_path, bytes).unwrap();
        let err = Artifact::load(&bad_path).unwrap_err();
        err.downcast_ref::<ArtifactError>()
            .unwrap_or_else(|| panic!("untyped artifact error: {err:?}"))
            .clone()
    };

    // flipped payload byte -> hash mismatch (detected before decoding)
    let mut corrupt = bytes.clone();
    *corrupt.last_mut().unwrap() ^= 0x40;
    assert!(matches!(typed(&corrupt), ArtifactError::HashMismatch { .. }));

    // truncation -> typed, never a panic or an over-allocation
    assert!(matches!(typed(&bytes[..bytes.len() / 2]), ArtifactError::Truncated));

    // foreign file -> bad magic
    let mut foreign = bytes.clone();
    foreign[0] = b'X';
    assert!(matches!(typed(&foreign), ArtifactError::BadMagic));

    // future format version -> typed version error naming the version
    let mut future = bytes.clone();
    future[4..8].copy_from_slice(&99u32.to_le_bytes());
    assert!(matches!(typed(&future), ArtifactError::UnsupportedVersion(99)));

    // a path already holding DIFFERENT bytes is never clobbered
    std::fs::write(&bad_path, b"precious non-artifact data").unwrap();
    let err = live.save_artifact(&bad_path).unwrap_err();
    assert!(
        matches!(err.downcast_ref::<ArtifactError>(), Some(ArtifactError::ClobberMismatch { .. })),
        "expected ClobberMismatch, got {err:?}"
    );
    assert_eq!(
        std::fs::read(&bad_path).unwrap(),
        b"precious non-artifact data",
        "the existing file must survive the refused save"
    );

    std::fs::remove_file(&path).unwrap();
    std::fs::remove_file(&bad_path).unwrap();
}
