//! Application-layer integration tests (§5 apps over real artifacts).
//! Requires `make artifacts`.

use deltagrad::apps::{conformal, influence, jackknife, privacy, robust, valuation};
use deltagrad::config::HyperParams;
use deltagrad::data::{sample_removal, synth, IndexSet};
use deltagrad::deltagrad::batch;
use deltagrad::runtime::Engine;
use deltagrad::train::{self, TrainOpts};
use deltagrad::util::vecmath::dist2;
use deltagrad::util::Rng;

struct Fixture {
    eng: Engine,
    exes: std::rc::Rc<deltagrad::ModelExes>,
    train_ds: deltagrad::Dataset,
    test_ds: deltagrad::Dataset,
    hp: HyperParams,
    w: Vec<f32>,
    traj: deltagrad::train::Trajectory,
}

fn fixture() -> Fixture {
    let mut eng = Engine::open_default().expect("make artifacts");
    let exes = eng.model("small").unwrap();
    let spec = exes.spec.clone();
    let (train_ds, test_ds) = synth::train_test_for_spec(&spec, 21, Some(768), Some(384));
    let mut hp = HyperParams::for_dataset("small");
    hp.t = 60;
    hp.j0 = 8;
    let out = train::train(&exes, &eng.rt, &train_ds, &TrainOpts::full(&hp, &IndexSet::empty()))
        .unwrap();
    Fixture {
        eng,
        exes,
        train_ds,
        test_ds,
        hp,
        w: out.w,
        traj: out.traj.unwrap(),
    }
}

#[test]
fn valuation_identifies_self_influence() {
    let f = fixture();
    let candidates: Vec<usize> = (0..6).collect();
    let values = valuation::leave_one_out_values(
        &f.exes, &f.eng.rt, &f.train_ds, &f.test_ds, &f.traj, &f.hp, &f.w, &candidates,
    )
    .unwrap();
    assert_eq!(values.len(), 6);
    for v in &values {
        assert!(v.param_dist > 0.0, "removal must move the params");
        assert!(v.param_dist < 1.0, "single-sample influence must be small");
    }
}

#[test]
fn jackknife_runs_and_bias_is_finite() {
    let f = fixture();
    // functional: ||w||^2 (a biased plug-in statistic)
    let res = jackknife::jackknife_bias(
        &f.exes,
        &f.eng.rt,
        &f.train_ds,
        &f.traj,
        &f.hp,
        &f.w,
        |w| deltagrad::util::vecmath::dot(w, w),
        5,
        3,
    )
    .unwrap();
    assert_eq!(res.n_loo, 5);
    assert!(res.full > 0.0);
    assert!(res.bias.is_finite());
    assert!((res.corrected - (res.full - res.bias)).abs() < 1e-9);
}

#[test]
fn conformal_residuals_and_coverage() {
    let f = fixture();
    let residuals = conformal::cross_conformal_residuals(
        &f.exes, &f.eng.rt, &f.train_ds, &f.traj, &f.hp, 4,
    )
    .unwrap();
    assert_eq!(residuals.len(), f.train_ds.n);
    assert!(residuals.iter().all(|r| (0.0..=1.0).contains(r)));
    // empirical coverage on the test set at alpha = 0.1 should be ~0.9
    let spec = &f.exes.spec;
    let alpha = 0.1;
    let mut covered = 0usize;
    let mut total_size = 0usize;
    for i in 0..f.test_ds.n {
        let set = conformal::prediction_set(
            &residuals, alpha, spec.da, spec.k, &f.w, f.test_ds.row(i),
        );
        if set.contains(&f.test_ds.y[i]) {
            covered += 1;
        }
        total_size += set.len();
    }
    let cov = covered as f64 / f.test_ds.n as f64;
    assert!(cov >= 1.0 - alpha - 0.07, "coverage {cov} too low");
    // sets must be informative (not always all k classes)
    assert!(
        (total_size as f64 / f.test_ds.n as f64) < spec.k as f64,
        "prediction sets are trivial"
    );
}

#[test]
fn influence_comparator_is_worse_than_deltagrad() {
    // d3's claim: the one-shot influence update is cheap but its error
    // does not track the exact retrain as closely as DeltaGrad's
    let f = fixture();
    let removed = sample_removal(&mut Rng::new(5), f.train_ds.n, 8);
    let basel = train::train(&f.exes, &f.eng.rt, &f.train_ds, &TrainOpts::full(&f.hp, &removed))
        .unwrap();
    let dg = batch::delete_gd(&f.exes, &f.eng.rt, &f.train_ds, &f.traj, &f.hp, &removed).unwrap();
    let (w_inf, _) = influence::influence_delete(
        &f.exes,
        &f.eng.rt,
        &f.train_ds,
        &f.w,
        &removed,
        &influence::InfluenceOpts { hessian_sample: 512, ..Default::default() },
    )
    .unwrap();
    let d_dg = dist2(&dg.w, &basel.w);
    let d_inf = dist2(&w_inf, &basel.w);
    let d_noop = dist2(&f.w, &basel.w);
    assert!(d_inf < d_noop, "influence should improve on doing nothing");
    assert!(d_dg < d_inf, "DeltaGrad ({d_dg:.2e}) should beat influence ({d_inf:.2e})");
}

#[test]
fn privacy_release_hides_the_deletion_error() {
    let f = fixture();
    let removed = sample_removal(&mut Rng::new(9), f.train_ds.n, 5);
    let basel = train::train(&f.exes, &f.eng.rt, &f.train_ds, &TrainOpts::full(&f.hp, &removed))
        .unwrap();
    let dg = batch::delete_gd(&f.exes, &f.eng.rt, &f.train_ds, &f.traj, &f.hp, &removed).unwrap();
    let delta0 = dist2(&dg.w, &basel.w);
    let mech = privacy::LaplaceMechanism::from_deletion_error(f.exes.spec.p, delta0, 1.0);
    let bound = privacy::epsilon_bound(&dg.w, &basel.w, mech.scale);
    // the √p factor makes the ℓ1-based worst case ≤ ε=1
    assert!(bound <= 1.0 + 1e-6, "ε bound {bound} exceeds the budget");
    let mut rng = Rng::new(1);
    let z = mech.release(&dg.w, &mut rng);
    assert!(mech.privacy_loss(&dg.w, &basel.w, &z) <= bound + 1e-9);
}

#[test]
fn robust_prune_refit_matches_basel() {
    let f = fixture();
    let (poisoned, _victims) = robust::inject_label_flips(&f.train_ds, 30, 17);
    let out = train::train(&f.exes, &f.eng.rt, &poisoned, &TrainOpts::full(&f.hp, &IndexSet::empty()))
        .unwrap();
    let traj = out.traj.unwrap();
    let fit = robust::prune_and_refit(&f.exes, &f.eng.rt, &poisoned, &traj, &f.hp, &out.w, 0.04)
        .unwrap();
    let basel = train::train(&f.exes, &f.eng.rt, &poisoned, &TrainOpts::full(&f.hp, &fit.pruned))
        .unwrap();
    let gap = dist2(&fit.w, &basel.w);
    let moved = dist2(&out.w, &basel.w);
    assert!(gap < 0.3 * moved.max(1e-12), "refit {gap:.2e} should track BaseL ({moved:.2e})");
}
