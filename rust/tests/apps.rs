//! Application-layer integration tests (§5 apps over real artifacts),
//! all sharing one Session per fixture. Requires `make artifacts`.

use deltagrad::apps::{conformal, influence, jackknife, privacy, robust, valuation};
use deltagrad::config::HyperParams;
use deltagrad::data::{sample_removal, synth};
use deltagrad::runtime::Engine;
use deltagrad::session::{Edit, Session, SessionBuilder};
use deltagrad::util::vecmath::dist2;
use deltagrad::util::Rng;

fn fixture() -> Session {
    let mut eng = Engine::open_default().expect("make artifacts");
    let spec = eng.spec("small").unwrap().clone();
    let (train_ds, test_ds) = synth::train_test_for_spec(&spec, 21, Some(768), Some(384));
    let mut hp = HyperParams::for_dataset("small");
    hp.t = 60;
    hp.j0 = 8;
    SessionBuilder::new("small")
        .hyper_params(hp)
        .datasets(train_ds, test_ds)
        .build_in(&mut eng)
        .unwrap()
}

#[test]
fn valuation_identifies_self_influence() {
    let session = fixture();
    let candidates: Vec<usize> = (0..6).collect();
    let values = valuation::leave_one_out_values(&session, &candidates).unwrap();
    assert_eq!(values.len(), 6);
    for v in &values {
        assert!(v.param_dist > 0.0, "removal must move the params");
        assert!(v.param_dist < 1.0, "single-sample influence must be small");
    }
    // all six LOO models were speculative: nothing committed
    assert_eq!(session.version(), 0);
    assert_eq!(session.stats().previews, 6);
}

#[test]
fn jackknife_runs_and_bias_is_finite() {
    let session = fixture();
    // functional: ||w||^2 (a biased plug-in statistic)
    let res =
        jackknife::jackknife_bias(&session, |w| deltagrad::util::vecmath::dot(w, w), 5, 3)
            .unwrap();
    assert_eq!(res.n_loo, 5);
    assert!(res.full > 0.0);
    assert!(res.bias.is_finite());
    assert!((res.corrected - (res.full - res.bias)).abs() < 1e-9);
    assert!(res.transfers.uploads > 0, "LOO passes must report traffic");
}

#[test]
fn conformal_residuals_and_coverage() {
    let session = fixture();
    let residuals = conformal::cross_conformal_residuals(&session, 4).unwrap();
    let test_ds = session.test_dataset();
    assert_eq!(residuals.len(), session.train_dataset().n);
    assert!(residuals.iter().all(|r| (0.0..=1.0).contains(r)));
    // empirical coverage on the test set at alpha = 0.1 should be ~0.9
    let spec = session.spec();
    let alpha = 0.1;
    let mut covered = 0usize;
    let mut total_size = 0usize;
    for i in 0..test_ds.n {
        let set = conformal::prediction_set(
            &residuals, alpha, spec.da, spec.k, session.w(), test_ds.row(i),
        );
        if set.contains(&test_ds.y[i]) {
            covered += 1;
        }
        total_size += set.len();
    }
    let cov = covered as f64 / test_ds.n as f64;
    assert!(cov >= 1.0 - alpha - 0.07, "coverage {cov} too low");
    // sets must be informative (not always all k classes)
    assert!(
        (total_size as f64 / test_ds.n as f64) < spec.k as f64,
        "prediction sets are trivial"
    );
}

#[test]
fn influence_comparator_is_worse_than_deltagrad() {
    // d3's claim: the one-shot influence update is cheap but its error
    // does not track the exact retrain as closely as DeltaGrad's
    let session = fixture();
    let removed = sample_removal(&mut Rng::new(5), session.train_dataset().n, 8);
    let edit = Edit::Delete(removed.clone());
    let basel = session.baseline(&edit).unwrap();
    let dg = session.preview(&edit).unwrap();
    let (w_inf, _) = influence::influence_delete(
        &session,
        &removed,
        &influence::InfluenceOpts { hessian_sample: 512, ..Default::default() },
    )
    .unwrap();
    let d_dg = dist2(&dg.out.w, &basel.w);
    let d_inf = dist2(&w_inf, &basel.w);
    let d_noop = dist2(session.w(), &basel.w);
    assert!(d_inf < d_noop, "influence should improve on doing nothing");
    assert!(d_dg < d_inf, "DeltaGrad ({d_dg:.2e}) should beat influence ({d_inf:.2e})");
}

#[test]
fn privacy_release_hides_the_deletion_error() {
    let session = fixture();
    let removed = sample_removal(&mut Rng::new(9), session.train_dataset().n, 5);
    let edit = Edit::Delete(removed);
    let basel = session.baseline(&edit).unwrap();
    let dg = session.preview(&edit).unwrap();
    let delta0 = dist2(&dg.out.w, &basel.w);
    let mech = privacy::LaplaceMechanism::from_deletion_error(session.spec().p, delta0, 1.0);
    let bound = privacy::epsilon_bound(&dg.out.w, &basel.w, mech.scale);
    // the √p factor makes the ℓ1-based worst case ≤ ε=1
    assert!(bound <= 1.0 + 1e-6, "ε bound {bound} exceeds the budget");
    let mut rng = Rng::new(1);
    let z = mech.release(&dg.out.w, &mut rng);
    assert!(mech.privacy_loss(&dg.out.w, &basel.w, &z) <= bound + 1e-9);
}

#[test]
fn robust_prune_refit_matches_basel() {
    // poisoned data needs its own session (the prune signal is the
    // session's own training loss)
    let mut eng = Engine::open_default().expect("make artifacts");
    let spec = eng.spec("small").unwrap().clone();
    let (train_ds, test_ds) = synth::train_test_for_spec(&spec, 21, Some(768), Some(384));
    let (poisoned, _victims) = robust::inject_label_flips(&train_ds, 30, 17);
    let mut hp = HyperParams::for_dataset("small");
    hp.t = 60;
    hp.j0 = 8;
    let session = SessionBuilder::new("small")
        .hyper_params(hp)
        .datasets(poisoned, test_ds)
        .build_in(&mut eng)
        .unwrap();
    let fit = robust::prune_and_refit(&session, 0.04).unwrap();
    let basel = session.baseline(&Edit::Delete(fit.pruned.clone())).unwrap();
    let gap = dist2(&fit.w, &basel.w);
    let moved = dist2(session.w(), &basel.w);
    assert!(gap < 0.3 * moved.max(1e-12), "refit {gap:.2e} should track BaseL ({moved:.2e})");
}
