//! Application-layer integration tests (§5 apps over real artifacts),
//! all sharing one Session per fixture. Requires `make artifacts`.
//!
//! The apps are thin wrappers over the typed Query dispatcher now; the
//! old free-function forms survive as deprecated shims, and this file
//! pins the two surfaces bitwise-identical
//! (`query_dispatcher_matches_free_functions`).

#![allow(deprecated)]

use deltagrad::apps::{conformal, influence, jackknife, privacy, robust, valuation};
use deltagrad::config::HyperParams;
use deltagrad::data::{sample_removal, synth};
use deltagrad::runtime::Engine;
use deltagrad::session::{
    Edit, JackknifeFunctional, Query, QueryResult, Session, SessionBuilder,
};
use deltagrad::util::vecmath::dist2;
use deltagrad::util::Rng;

fn fixture() -> Session {
    let mut eng = Engine::open_default().expect("make artifacts");
    let spec = eng.spec("small").unwrap().clone();
    let (train_ds, test_ds) = synth::train_test_for_spec(&spec, 21, Some(768), Some(384));
    let mut hp = HyperParams::for_dataset("small");
    hp.t = 60;
    hp.j0 = 8;
    SessionBuilder::new("small")
        .hyper_params(hp)
        .datasets(train_ds, test_ds)
        .build_in(&mut eng)
        .unwrap()
}

#[test]
fn valuation_identifies_self_influence() {
    let session = fixture();
    let candidates: Vec<usize> = (0..6).collect();
    let values = valuation::leave_one_out_values(&session, &candidates).unwrap();
    assert_eq!(values.len(), 6);
    for v in &values {
        assert!(v.param_dist > 0.0, "removal must move the params");
        assert!(v.param_dist < 1.0, "single-sample influence must be small");
    }
    // all six LOO models were speculative: nothing committed
    assert_eq!(session.version(), 0);
    assert_eq!(session.stats().previews, 6);
}

#[test]
fn jackknife_runs_and_bias_is_finite() {
    let session = fixture();
    // functional: ||w||^2 (a biased plug-in statistic)
    let res =
        jackknife::jackknife_bias(&session, |w| deltagrad::util::vecmath::dot(w, w), 5, 3)
            .unwrap();
    assert_eq!(res.n_loo, 5);
    assert!(res.full > 0.0);
    assert!(res.bias.is_finite());
    assert!((res.corrected - (res.full - res.bias)).abs() < 1e-9);
    assert!(res.transfers.uploads > 0, "LOO passes must report traffic");
}

#[test]
fn conformal_residuals_and_coverage() {
    let session = fixture();
    let residuals = conformal::cross_conformal_residuals(&session, 4).unwrap();
    let test_ds = session.test_dataset();
    assert_eq!(residuals.len(), session.train_dataset().n);
    assert!(residuals.iter().all(|r| (0.0..=1.0).contains(r)));
    // empirical coverage on the test set at alpha = 0.1 should be ~0.9
    let spec = session.spec();
    let alpha = 0.1;
    let mut covered = 0usize;
    let mut total_size = 0usize;
    for i in 0..test_ds.n {
        let set = conformal::prediction_set(
            &residuals, alpha, spec.da, spec.k, session.w(), test_ds.row(i),
        );
        if set.contains(&test_ds.y[i]) {
            covered += 1;
        }
        total_size += set.len();
    }
    let cov = covered as f64 / test_ds.n as f64;
    assert!(cov >= 1.0 - alpha - 0.07, "coverage {cov} too low");
    // sets must be informative (not always all k classes)
    assert!(
        (total_size as f64 / test_ds.n as f64) < spec.k as f64,
        "prediction sets are trivial"
    );
}

#[test]
fn influence_comparator_is_worse_than_deltagrad() {
    // d3's claim: the one-shot influence update is cheap but its error
    // does not track the exact retrain as closely as DeltaGrad's
    let session = fixture();
    let removed = sample_removal(&mut Rng::new(5), session.train_dataset().n, 8);
    let edit = Edit::Delete(removed.clone());
    let basel = session.baseline(&edit).unwrap();
    let dg = session.preview(&edit).unwrap();
    let (w_inf, _) = influence::influence_delete(
        &session,
        &removed,
        &influence::InfluenceOpts { hessian_sample: 512, ..Default::default() },
    )
    .unwrap();
    let d_dg = dist2(&dg.out.w, &basel.w);
    let d_inf = dist2(&w_inf, &basel.w);
    let d_noop = dist2(session.w(), &basel.w);
    assert!(d_inf < d_noop, "influence should improve on doing nothing");
    assert!(d_dg < d_inf, "DeltaGrad ({d_dg:.2e}) should beat influence ({d_inf:.2e})");
}

#[test]
fn privacy_release_hides_the_deletion_error() {
    let session = fixture();
    let removed = sample_removal(&mut Rng::new(9), session.train_dataset().n, 5);
    let edit = Edit::Delete(removed);
    let basel = session.baseline(&edit).unwrap();
    let dg = session.preview(&edit).unwrap();
    let delta0 = dist2(&dg.out.w, &basel.w);
    let mech =
        privacy::LaplaceMechanism::from_deletion_error(session.spec().p, delta0, 1.0).unwrap();
    let bound = privacy::epsilon_bound(&dg.out.w, &basel.w, mech.scale);
    // the √p factor makes the ℓ1-based worst case ≤ ε=1
    assert!(bound <= 1.0 + 1e-6, "ε bound {bound} exceeds the budget");
    let mut rng = Rng::new(1);
    let z = mech.release(&dg.out.w, &mut rng);
    assert!(mech.privacy_loss(&dg.out.w, &basel.w, &z) <= bound + 1e-9);
}

#[test]
fn query_dispatcher_matches_free_functions() {
    // the api_redesign acceptance pin: every app answers IDENTICALLY
    // through the new Query dispatcher and its old free-function form.
    // The manual loops below replicate the pre-redesign bodies, so the
    // pin is against the old behaviour, not shim-vs-shim identity.
    let session = fixture();

    // --- valuation: query vs a hand-rolled preview loop (bitwise; the
    // second run's previews hit the cross-pass row cache)
    let candidates: Vec<usize> = vec![2, 11, 40];
    let manual: Vec<(f64, f64)> = {
        let w_full = session.w().to_vec();
        let base_loss = session.eval_test(&w_full).unwrap().mean_loss();
        candidates
            .iter()
            .map(|&i| {
                let pv = session.preview(&Edit::delete_row(i)).unwrap();
                let stats = session.eval_test(&pv.out.w).unwrap();
                (stats.mean_loss() - base_loss, dist2(&pv.out.w, &w_full))
            })
            .collect()
    };
    let reply = session
        .query(&Query::Valuation { candidates: candidates.clone() })
        .unwrap();
    assert_eq!(reply.version, 0);
    let values = match reply.result {
        QueryResult::Valuation { values } => values,
        other => panic!("wrong kind: {other:?}"),
    };
    assert_eq!(values.len(), manual.len());
    for (v, (loss_delta, param_dist)) in values.iter().zip(&manual) {
        assert_eq!(v.loss_delta, *loss_delta, "valuation loss drifted through the dispatcher");
        assert_eq!(v.param_dist, *param_dist, "valuation dist drifted through the dispatcher");
    }
    // and the deprecated shim returns the same floats
    let shim = valuation::leave_one_out_values(&session, &candidates).unwrap();
    for (a, b) in shim.iter().zip(&values) {
        assert_eq!((a.index, a.loss_delta, a.param_dist), (b.index, b.loss_delta, b.param_dist));
    }

    // --- conformal: query vs the hand-rolled fold loop (bitwise)
    let spec = session.spec().clone();
    let manual_res: Vec<f64> = {
        let ds = session.train_dataset();
        let mut residuals = vec![0.0f64; ds.n];
        for fold in conformal::folds(ds.n, 4) {
            let pv = session.preview(&Edit::Delete(fold.clone())).unwrap();
            for i in fold.iter() {
                residuals[i] =
                    conformal::nonconformity_lr(spec.da, spec.k, &pv.out.w, ds.row(i), ds.y[i]);
            }
        }
        residuals
    };
    let x0 = session.test_dataset().row(0).to_vec();
    let reply = session
        .query(&Query::Conformal { alpha: 0.1, folds: 4, x: Some(x0.clone()) })
        .unwrap();
    let (residuals, threshold, set) = match reply.result {
        QueryResult::Conformal { residuals, threshold, set } => (residuals, threshold, set),
        other => panic!("wrong kind: {other:?}"),
    };
    assert_eq!(residuals, manual_res, "conformal residuals drifted through the dispatcher");
    assert_eq!(threshold, conformal::residual_threshold(&manual_res, 0.1));
    assert_eq!(
        set.unwrap(),
        conformal::prediction_set(&manual_res, 0.1, spec.da, spec.k, session.w(), &x0)
    );
    assert_eq!(
        conformal::cross_conformal_residuals(&session, 4).unwrap(),
        manual_res,
        "deprecated conformal shim drifted"
    );

    // --- influence: shim vs dispatcher (deterministic CG: bitwise)
    let removed = sample_removal(&mut Rng::new(3), session.train_dataset().n, 6);
    let opts = influence::InfluenceOpts { hessian_sample: 256, ..Default::default() };
    let (w_shim, _) = influence::influence_delete(&session, &removed, &opts).unwrap();
    let reply = session
        .query(&Query::Influence { targets: removed.clone(), opts })
        .unwrap();
    let w_disp = match reply.result {
        QueryResult::Influence { w, .. } => w,
        other => panic!("wrong kind: {other:?}"),
    };
    assert_eq!(w_shim, w_disp, "influence drifted through the dispatcher");

    // --- jackknife: typed functional vs the closure form (bitwise)
    let shim = jackknife::jackknife_bias(&session, |w| deltagrad::util::vecmath::dot(w, w), 4, 9)
        .unwrap();
    let reply = session
        .query(&Query::Jackknife {
            functional: JackknifeFunctional::ParamNormSq,
            loo: 4,
            seed: 9,
        })
        .unwrap();
    let disp = match reply.result {
        QueryResult::Jackknife(j) => j,
        other => panic!("wrong kind: {other:?}"),
    };
    assert_eq!(shim.full, disp.full);
    assert_eq!(shim.bias, disp.bias, "jackknife drifted through the dispatcher");
    assert_eq!(shim.n_loo, disp.n_loo);

    // --- robust: shim vs dispatcher (bitwise)
    let shim = robust::prune_and_refit(&session, 0.02).unwrap();
    let reply = session.query(&Query::RobustSweep { frac: 0.02 }).unwrap();
    let disp = match reply.result {
        QueryResult::Robust(fit) => fit,
        other => panic!("wrong kind: {other:?}"),
    };
    assert_eq!(shim.pruned.as_slice(), disp.pruned.as_slice());
    assert_eq!(shim.w, disp.w, "robust refit drifted through the dispatcher");

    // --- predict + loss sanity: host softmax agrees with eval counts
    let reply = session.query(&Query::Predict { x: x0 }).unwrap();
    match reply.result {
        QueryResult::Predict { label, probs } => {
            assert_eq!(probs.len(), spec.k);
            assert!((probs.iter().sum::<f64>() - 1.0).abs() < 1e-9);
            assert!((label as usize) < spec.k);
            // zero device traffic: prediction is host-side
            assert_eq!(reply.transfers.uploads, 0);
            assert_eq!(reply.transfers.downloads, 0);
        }
        other => panic!("wrong kind: {other:?}"),
    }
    let reply = session.query(&Query::Loss).unwrap();
    match reply.result {
        QueryResult::Loss { test_accuracy, train_accuracy, .. } => {
            assert!(test_accuracy > 0.5);
            assert!(train_accuracy > 0.5);
        }
        other => panic!("wrong kind: {other:?}"),
    }
    // nothing above committed anything
    assert_eq!(session.version(), 0);
}

#[test]
fn preview_loop_queries_survive_committed_deletions() {
    // the interleaved read/write contract for the preview-loop kinds:
    // after a delete commit, conformal folds, jackknife draws, and the
    // robust prune set must all skip the removed rows instead of
    // tripping "already deleted" (and deleted rows get no residual)
    let mut session = fixture();
    session.commit(Edit::delete_row(0)).unwrap();
    session.commit(Edit::delete_row(7)).unwrap();

    let reply = session
        .query(&Query::Conformal { alpha: 0.1, folds: 4, x: None })
        .unwrap();
    match reply.result {
        QueryResult::Conformal { residuals, threshold, .. } => {
            assert_eq!(residuals.len(), session.train_dataset().n);
            assert!(residuals[0].is_nan(), "deleted rows must carry no residual");
            assert!(residuals[7].is_nan());
            assert!(residuals[1].is_finite());
            assert!(threshold.is_finite());
        }
        other => panic!("wrong kind: {other:?}"),
    }

    let reply = session.query(&Query::RobustSweep { frac: 0.02 }).unwrap();
    match reply.result {
        QueryResult::Robust(fit) => {
            assert!(!fit.pruned.contains(0), "prune set must skip removed rows");
            assert!(!fit.pruned.contains(7));
        }
        other => panic!("wrong kind: {other:?}"),
    }

    let reply = session
        .query(&Query::Jackknife {
            functional: JackknifeFunctional::ParamNormSq,
            loo: 6,
            seed: 11,
        })
        .unwrap();
    match reply.result {
        QueryResult::Jackknife(j) => assert!(j.bias.is_finite()),
        other => panic!("wrong kind: {other:?}"),
    }

    // bad parameters reject (typed error), never panic the caller
    assert!(session.query(&Query::RobustSweep { frac: 1.5 }).is_err());
    assert!(session.query(&Query::RobustSweep { frac: f64::NAN }).is_err());
    assert!(session
        .query(&Query::Conformal { alpha: 1.5, folds: 4, x: None })
        .is_err());
    assert!(session
        .query(&Query::Conformal { alpha: 0.1, folds: 0, x: None })
        .is_err());
    let da = session.spec().da;
    assert!(session
        .query(&Query::Predict { x: vec![f32::NAN; da] })
        .is_err());
    assert!(session
        .query(&Query::Jackknife {
            functional: JackknifeFunctional::ParamNormSq,
            loo: 0,
            seed: 1,
        })
        .is_err());
    // influence targets validate like the write plane: deleted rows,
    // out-of-range rows, and empty sets reject instead of silently
    // computing a double-deletion estimate
    let opts = influence::InfluenceOpts::default();
    assert!(session
        .query(&Query::Influence {
            targets: deltagrad::data::IndexSet::from_vec(vec![0]),
            opts
        })
        .is_err());
    assert!(session
        .query(&Query::Influence {
            targets: deltagrad::data::IndexSet::from_vec(vec![session.train_dataset().n]),
            opts
        })
        .is_err());
    assert!(session
        .query(&Query::Influence {
            targets: deltagrad::data::IndexSet::empty(),
            opts
        })
        .is_err());
    // and a live target set still answers
    assert!(session
        .query(&Query::Influence {
            targets: deltagrad::data::IndexSet::from_vec(vec![3, 9]),
            opts: influence::InfluenceOpts { hessian_sample: 128, cg_iters: 5, ..opts }
        })
        .is_ok());
}

#[test]
fn robust_prune_refit_matches_basel() {
    // poisoned data needs its own session (the prune signal is the
    // session's own training loss)
    let mut eng = Engine::open_default().expect("make artifacts");
    let spec = eng.spec("small").unwrap().clone();
    let (train_ds, test_ds) = synth::train_test_for_spec(&spec, 21, Some(768), Some(384));
    let (poisoned, _victims) = robust::inject_label_flips(&train_ds, 30, 17);
    let mut hp = HyperParams::for_dataset("small");
    hp.t = 60;
    hp.j0 = 8;
    let session = SessionBuilder::new("small")
        .hyper_params(hp)
        .datasets(poisoned, test_ds)
        .build_in(&mut eng)
        .unwrap();
    let fit = robust::prune_and_refit(&session, 0.04).unwrap();
    let basel = session.baseline(&Edit::Delete(fit.pruned.clone())).unwrap();
    let gap = dist2(&fit.w, &basel.w);
    let moved = dist2(session.w(), &basel.w);
    assert!(gap < 0.3 * moved.max(1e-12), "refit {gap:.2e} should track BaseL ({moved:.2e})");
}
