//! Cross-module property tests (hand-rolled harness; see testing::prop).
//! These cover coordinator/data/algorithm invariants that hold for ALL
//! inputs, not just the fixtures in the unit tests. No artifacts needed.

use deltagrad::data::{sample_removal, synth, Dataset, IndexSet};
use deltagrad::lbfgs::History;
use deltagrad::testing::prop::Cases;
use deltagrad::util::vecmath::{dist2, dot};
use deltagrad::util::Rng;

#[test]
fn prop_indexset_complement_partitions() {
    Cases::new(0x1D5E7).run(200, |g| {
        let n = 1 + g.below(300);
        let r = g.below(n + 1);
        let set = IndexSet::from_vec(g.distinct(n, r));
        let comp = set.complement(n);
        assert_eq!(set.len() + comp.len(), n);
        for &i in &comp {
            assert!(!set.contains(i));
        }
        for i in set.iter() {
            assert!(!comp.contains(&i));
        }
    });
}

#[test]
fn prop_chunk_padding_covers_every_row_once() {
    Cases::new(0xC4A9).run(100, |g| {
        let d = 1 + g.below(8);
        let k = 2 + g.below(4);
        let n = 1 + g.below(200);
        let chunk = 1 + g.below(64);
        let params = synth::SynthParams { d, k, sep: 1.0, sparsity: 0.0, label_noise: 0.0 };
        let ds = synth::generate(&params, 5, n);
        let r = g.below(n.min(10) + 1);
            let removed = IndexSet::from_vec(g.distinct(n, r));
        let mut mask_total = 0.0f64;
        let mut x_checksum = 0.0f64;
        for c in 0..ds.n_chunks(chunk) {
            let (x, _y, m) = ds.chunk_padded(c, chunk, &removed);
            assert_eq!(x.len(), chunk * ds.da);
            assert_eq!(m.len(), chunk);
            mask_total += m.iter().map(|&v| v as f64).sum::<f64>();
            x_checksum += x.iter().map(|&v| v as f64).sum::<f64>();
        }
        assert_eq!(mask_total as usize, n - removed.len());
        let direct: f64 = ds.x.iter().map(|&v| v as f64).sum();
        assert!((x_checksum - direct).abs() < 1e-3 * direct.abs().max(1.0));
    });
}

#[test]
fn prop_gather_roundtrip() {
    Cases::new(0x6A7A).run(100, |g| {
        let d = 1 + g.below(6);
        let params = synth::SynthParams { d, k: 3, sep: 1.0, sparsity: 0.0, label_noise: 0.0 };
        let n = 5 + g.below(100);
        let ds = synth::generate(&params, 9, n);
        let count = 1 + g.below(n);
        let idxs = g.distinct(n, count);
        let chunk = 1 + g.below(32);
        let groups = ds.gather_padded(&idxs, chunk);
        let mut flat_rows = 0usize;
        for (gi, (x, y, m)) in groups.iter().enumerate() {
            for r in 0..chunk {
                let global = gi * chunk + r;
                if global < idxs.len() {
                    assert_eq!(m[r], 1.0);
                    let src = ds.row(idxs[global]);
                    assert_eq!(&x[r * ds.da..(r + 1) * ds.da], src);
                    let label = ds.y[idxs[global]] as usize;
                    assert_eq!(y[r * ds.k + label], 1.0);
                    flat_rows += 1;
                } else {
                    assert_eq!(m[r], 0.0);
                }
            }
        }
        assert_eq!(flat_rows, idxs.len());
    });
}

#[test]
fn prop_lbfgs_secant_and_spd_on_random_spd_hessians() {
    Cases::new(0x1BF65).run(60, |g| {
        let p = 4 + g.below(24);
        let m = 1 + g.below(4.min(p));
        // random SPD Hessian H = A A^T/p + I
        let a: Vec<f64> = (0..p * p).map(|_| g.gaussian() as f64).collect();
        let hmat = |i: usize, j: usize| -> f64 {
            let mut acc = if i == j { 1.0 } else { 0.0 };
            for k in 0..p {
                acc += a[i * p + k] * a[j * p + k] / p as f64;
            }
            acc
        };
        let mut hist = History::new(m);
        let mut last = (vec![], vec![]);
        for _ in 0..m {
            let dw = g.vec_f32(p, 1.0);
            let dg: Vec<f32> = (0..p)
                .map(|i| (0..p).map(|j| hmat(i, j) * dw[j] as f64).sum::<f64>() as f32)
                .collect();
            hist.push(dw.clone(), dg.clone());
            last = (dw, dg);
        }
        // secant: B s_last = y_last
        let bs = hist.bv(&last.0).expect("solvable");
        let denom = last.1.iter().map(|x| x.abs()).fold(1.0f32, f32::max) as f64;
        assert!(
            dist2(&bs, &last.1) / denom < 5e-2,
            "secant violation {:.3e}",
            dist2(&bs, &last.1) / denom
        );
        // positive definiteness along random directions (Lemma 6)
        for _ in 0..5 {
            let v = g.vec_f32(p, 1.0);
            let bv = hist.bv(&v).unwrap();
            assert!(dot(&v, &bv) > 0.0, "B not PD");
        }
    });
}

#[test]
fn prop_removal_sets_within_range_and_exact_size() {
    Cases::new(0xDE1E7E).run(200, |g| {
        let n = 2 + g.below(1000);
        let r = g.below(n);
        let mut rng = Rng::new(g.below(1 << 30) as u64);
        let set = sample_removal(&mut rng, n, r);
        assert_eq!(set.len(), r);
        assert!(set.iter().all(|i| i < n));
    });
}

#[test]
fn prop_dataset_append_preserves_rows() {
    Cases::new(0xAB3D).run(100, |g| {
        let d = 1 + g.below(5);
        let params = synth::SynthParams { d, k: 2, sep: 1.0, sparsity: 0.0, label_noise: 0.0 };
        let n1 = 1 + g.below(50);
        let n2 = 1 + g.below(50);
        let a = synth::generate(&params, 1, n1);
        let b = synth::generate_stream(&params, 1, 7, n2);
        let mut joined = a.clone();
        joined.append(&b);
        assert_eq!(joined.n, n1 + n2);
        let i = g.below(n1);
        assert_eq!(joined.row(i), a.row(i));
        let j = g.below(n2);
        assert_eq!(joined.row(n1 + j), b.row(j));
        assert_eq!(joined.y[n1 + j], b.y[j]);
    });
}

#[test]
fn prop_train_test_streams_share_distribution_marker() {
    // prototypes are seed-keyed: two streams of the same family/seed must
    // produce datasets whose class-conditional means are close, while two
    // different seeds must not (guards the train/test mismatch bug).
    let params = synth::SynthParams { d: 12, k: 2, sep: 3.0, sparsity: 0.0, label_noise: 0.0 };
    let class_mean = |ds: &Dataset, c: u32| -> Vec<f64> {
        let mut acc = vec![0.0f64; ds.da - 1];
        let mut cnt = 0.0f64;
        for i in 0..ds.n {
            if ds.y[i] == c {
                for (j, slot) in acc.iter_mut().enumerate() {
                    *slot += ds.row(i)[j] as f64;
                }
                cnt += 1.0;
            }
        }
        acc.iter().map(|v| v / cnt.max(1.0)).collect()
    };
    let l2 = |a: &[f64], b: &[f64]| -> f64 {
        a.iter().zip(b).map(|(x, y)| (x - y).powi(2)).sum::<f64>().sqrt()
    };
    let tr = synth::generate_stream(&params, 11, 0, 2000);
    let te = synth::generate_stream(&params, 11, 1, 2000);
    let other = synth::generate_stream(&params, 12, 0, 2000);
    for c in 0..2u32 {
        let same = l2(&class_mean(&tr, c), &class_mean(&te, c));
        let diff = l2(&class_mean(&tr, c), &class_mean(&other, c));
        assert!(same < 0.5, "train/test prototype drift {same}");
        assert!(diff > 1.0, "distinct seeds should have distinct prototypes");
    }
}
