//! Certified-deletion subsystem end to end: deterministic releases
//! across restore/replay/WAL recovery, the exact exhaustion boundary of
//! the (ε,δ) ledger, accountant survival through checkpoints, query
//! validation on the read plane, and the certification-off byte/traffic
//! identity. Requires `make artifacts`.

use std::path::PathBuf;
use std::time::Duration;

use deltagrad::config::HyperParams;
use deltagrad::coordinator::{BatchPolicy, Rejected, ServiceConfig, ServiceHandle, Supervision};
use deltagrad::session::{
    artifact, CertifyConfig, Edit, ExhaustionPolicy, Query, QueryResult, Session, SessionBuilder,
};

fn small_hp() -> HyperParams {
    let mut hp = HyperParams::for_dataset("small");
    hp.t = 40;
    hp.j0 = 6;
    hp.t0 = 5;
    hp
}

fn certified_session(cfg: CertifyConfig) -> Session {
    SessionBuilder::new("small")
        .seed(77)
        .n_train(Some(512))
        .n_test(Some(256))
        .hyper_params(small_hp())
        .certify(cfg)
        .build()
        .unwrap()
}

fn cfg() -> CertifyConfig {
    CertifyConfig::new(1.0, 1e-4).capacity(8).noise_seed(0xC0FFEE)
}

fn svc_cfg(certify: Option<CertifyConfig>) -> ServiceConfig {
    ServiceConfig {
        model: "small".into(),
        seed: 77,
        n_train: Some(512),
        n_test: Some(256),
        hp: small_hp(),
        policy: BatchPolicy {
            max_group: 1,
            max_wait: Duration::from_millis(1),
            ..BatchPolicy::default()
        },
        readers: 0,
        query_cache: 0,
        query_cache_bytes: 0,
        shards: 1,
        checkpoint_every: 0,
        checkpoint_dir: None,
        checkpoint_keep: 4,
        wal: false,
        restore_latest: false,
        store_fresh: false,
        supervision: Supervision::default(),
        faults: None,
        certify,
    }
}

fn tmp_path(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("deltagrad-test-certified-{tag}-{}.dgar", std::process::id()))
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

struct Store(PathBuf);

impl Store {
    fn new(tag: &str) -> Store {
        let p = std::env::temp_dir()
            .join(format!("deltagrad-test-certified-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&p);
        Store(p)
    }
    fn path(&self) -> &std::path::Path {
        &self.0
    }
}

impl Drop for Store {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}

fn budget_bits(r: &QueryResult) -> (u64, u64, u64, u64, u64, u64, u64, u64) {
    match r {
        QueryResult::PrivacyBudget {
            eps_spent,
            eps_budget,
            delta_spent,
            delta_budget,
            deletions,
            capacity,
            releases,
            retrains,
        } => (
            eps_spent.to_bits(),
            eps_budget.to_bits(),
            delta_spent.to_bits(),
            delta_budget.to_bits(),
            *deletions,
            *capacity,
            *releases,
            *retrains,
        ),
        other => panic!("wrong reply kind: {other:?}"),
    }
}

#[test]
fn release_is_deterministic_across_restore_and_replay() {
    // the released model is a pure function of (noise_seed, version,
    // internal state): a warm restore and a from-scratch edit-log replay
    // must publish the IDENTICAL noised vector, bitwise
    let mut live = certified_session(cfg());
    for i in 0..3 {
        live.commit(Edit::delete_row(i)).unwrap();
    }
    let released = live.release_current().unwrap();
    assert_ne!(bits(&released), bits(live.w()), "the release must actually be noised");

    let path = tmp_path("release");
    let _ = std::fs::remove_file(&path);
    live.save_artifact(&path).unwrap();

    let restored = SessionBuilder::restore_from(&path).unwrap();
    assert_eq!(restored.certified(), live.certified(), "restored ledger must match bitwise");
    assert_eq!(
        bits(&restored.release_current().unwrap()),
        bits(&released),
        "restored replica published a different release"
    );

    let replayed = artifact::replay(&path).unwrap();
    assert_eq!(replayed.certified(), live.certified(), "replayed ledger must match bitwise");
    assert_eq!(
        bits(&replayed.release_current().unwrap()),
        bits(&released),
        "edit-log replay published a different release"
    );
    let _ = std::fs::remove_file(&path);
}

#[test]
fn exhaustion_boundary_rejects_typed_and_the_worker_survives() {
    // capacity 3: commits 1..=3 admit, commit 4 rejects with the typed
    // Rejected::BudgetExhausted — and the worker keeps serving
    let svc = ServiceHandle::spawn(svc_cfg(Some(cfg().capacity(3)))).unwrap();
    for i in 0..3 {
        assert_eq!(svc.update(Edit::delete_row(i)).unwrap().version, (i + 1) as u64);
    }
    match svc.update(Edit::delete_row(3)) {
        Err(Rejected::BudgetExhausted { deletions, capacity, eps_spent, epsilon }) => {
            assert_eq!((deletions, capacity), (3, 3));
            assert!(eps_spent <= epsilon);
        }
        other => panic!("expected BudgetExhausted at capacity, got {other:?}"),
    }
    // the rejection left no trace: same version, the ledger still
    // answers, and the read plane still serves
    let rep = svc.query(Query::PrivacyBudget).unwrap();
    assert_eq!(rep.version, 3);
    let (_, _, _, _, deletions, capacity, releases, _) = budget_bits(&rep.result);
    assert_eq!((deletions, capacity, releases), (3, 3, 3));
    let m = svc.metrics().unwrap();
    assert_eq!(m.privacy_deletions, 3);
    assert_eq!(m.budget_rejects, 1);
    svc.shutdown().unwrap();
}

#[test]
fn accountant_survives_checkpoint_and_wal_recovery_bitwise() {
    // checkpoint v2 + WAL suffix to v3: restore_latest must recharge the
    // ledger through the replayed commit and land on the live session's
    // exact accountant bits — and a service spawned with restore_latest
    // must answer Query::PrivacyBudget with those same bits
    let store = Store::new("wal");
    let mut live = certified_session(cfg());
    let wal_p = artifact::wal_path(store.path(), "small");
    std::fs::create_dir_all(store.path()).unwrap();
    let mut wal = artifact::WalWriter::create(&wal_p).unwrap();
    for i in 0..3 {
        let c = live.commit(Edit::delete_row(i)).unwrap();
        wal.append(c.version, &Edit::delete_row(i)).unwrap();
        if c.version == 2 {
            artifact::save_to_store(&live, store.path()).unwrap();
        }
    }
    drop(wal);

    let recovered = artifact::restore_latest(store.path(), "small").unwrap();
    assert_eq!(recovered.version(), 3);
    assert_eq!(
        recovered.certified(),
        live.certified(),
        "WAL recovery must recharge the ledger to identical bits"
    );
    assert_eq!(bits(&recovered.release_current().unwrap()), bits(&live.release_current().unwrap()));

    let svc = ServiceHandle::spawn(ServiceConfig {
        restore_latest: true,
        wal: true,
        checkpoint_dir: Some(store.path().to_path_buf()),
        ..svc_cfg(Some(cfg()))
    })
    .unwrap();
    let rep = svc.query(Query::PrivacyBudget).unwrap();
    assert_eq!(rep.version, 3);
    let live_snap = live.certified().unwrap().snapshot();
    let (eps_spent, eps_budget, delta_spent, _, deletions, capacity, releases, retrains) =
        budget_bits(&rep.result);
    assert_eq!(eps_spent, live_snap.eps_spent.to_bits(), "eps ledger must match bitwise");
    assert_eq!(eps_budget, live_snap.eps_budget.to_bits());
    assert_eq!(delta_spent, live_snap.delta_spent.to_bits());
    assert_eq!(
        (deletions, capacity, releases, retrains),
        (live_snap.deletions, live_snap.capacity, live_snap.releases, live_snap.retrains)
    );
    svc.shutdown().unwrap();
}

#[test]
fn budget_and_certificate_queries_validate_without_killing_the_worker() {
    // certification off: both new kinds reject typed, the worker lives
    let svc = ServiceHandle::spawn(svc_cfg(None)).unwrap();
    match svc.query(Query::PrivacyBudget) {
        Err(Rejected::Failed(e)) => assert!(e.contains("certification is off"), "{e}"),
        other => panic!("expected a typed rejection, got {other:?}"),
    }
    match svc.query(Query::Certificate { version: 1 }) {
        Err(Rejected::Failed(e)) => assert!(e.contains("certification is off"), "{e}"),
        other => panic!("expected a typed rejection, got {other:?}"),
    }
    assert_eq!(svc.update(Edit::delete_row(0)).unwrap().version, 1);
    svc.shutdown().unwrap();

    // certification on: an unknown version rejects typed, a known one
    // serves the certificate
    let svc = ServiceHandle::spawn(svc_cfg(Some(cfg()))).unwrap();
    svc.update(Edit::delete_row(0)).unwrap();
    match svc.query(Query::Certificate { version: 99 }) {
        Err(Rejected::Failed(e)) => assert!(e.contains("no certificate"), "{e}"),
        other => panic!("expected a typed rejection, got {other:?}"),
    }
    let rep = svc.query(Query::Certificate { version: 1 }).unwrap();
    match &rep.result {
        QueryResult::Certificate { version, delta0, scale, eps_hat, mechanism } => {
            assert_eq!(*version, 1);
            assert!(*delta0 > 0.0 && *scale > 0.0 && *eps_hat > 0.0);
            assert_eq!(mechanism, "gaussian");
        }
        other => panic!("wrong reply kind: {other:?}"),
    }
    svc.shutdown().unwrap();
}

#[test]
fn certification_off_stays_bitwise_identical_with_zero_extra_traffic() {
    // the certified plane must be invisible when on (internal state) and
    // absent when off: same commits → same internal w bits AND the same
    // device-transfer counters, certified or not — the certificate is
    // measured from the accumulator tail the commit already downloads
    let mut plain = SessionBuilder::new("small")
        .seed(77)
        .n_train(Some(512))
        .n_test(Some(256))
        .hyper_params(small_hp())
        .build()
        .unwrap();
    let mut cert = certified_session(cfg());
    for i in 0..2 {
        plain.commit(Edit::delete_row(i)).unwrap();
        cert.commit(Edit::delete_row(i)).unwrap();
    }
    assert_eq!(
        bits(plain.w()),
        bits(cert.w()),
        "certification must never touch internal state"
    );
    let (pt, ct) = (plain.stats().commit_transfers, cert.stats().commit_transfers);
    assert_eq!(pt.uploads, ct.uploads, "certified commits must upload nothing extra");
    assert_eq!(pt.upload_floats, ct.upload_floats);
    assert_eq!(pt.downloads, ct.downloads, "certified commits must download nothing extra");
    assert_eq!(pt.download_floats, ct.download_floats);
    assert_eq!(pt.execs, ct.execs);

    // the uncertified artifact carries no privacy section: its bytes
    // round-trip through the pre-subsystem decoder shape
    let path = tmp_path("off");
    let _ = std::fs::remove_file(&path);
    plain.save_artifact(&path).unwrap();
    let restored = SessionBuilder::restore_from(&path).unwrap();
    assert!(restored.certified().is_none(), "no privacy section may appear uninvited");
    assert_eq!(bits(restored.w()), bits(plain.w()));
    let _ = std::fs::remove_file(&path);
}

#[test]
fn retrain_policy_resets_the_ledger_and_releases_exactly() {
    // capacity 2 + Retrain: the third deletion routes through a full
    // retrain, resets the ledger, and releases with zero noise
    let mut s = certified_session(cfg().capacity(2).policy(ExhaustionPolicy::Retrain));
    for i in 0..2 {
        s.commit(Edit::delete_row(i)).unwrap();
    }
    let before = s.certified().unwrap().snapshot();
    assert_eq!((before.deletions, before.retrains), (2, 0));

    s.commit(Edit::delete_row(2)).unwrap();
    let after = s.certified().unwrap().snapshot();
    assert_eq!(after.retrains, 1, "exhaustion under Retrain must trigger the reset");
    assert_eq!(after.deletions, 1, "the ledger restarts counting after the retrain");
    assert!(after.eps_spent < before.eps_spent, "the reset must drop spent eps");

    let rec = s.certified().unwrap().certificate(s.version()).unwrap();
    assert_eq!((rec.delta0, rec.scale, rec.eps_hat), (0.0, 0.0, 0.0));
    assert_eq!(
        bits(&s.release_current().unwrap()),
        bits(s.w()),
        "a retrained model has zero deletion error and releases exactly"
    );
}
